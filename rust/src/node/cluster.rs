//! Multi-node threaded runtime: workers + comm thread + migrate thread
//! per node, Safra termination, steal protocol over the message fabric.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::comm::{LinkModel, Msg, Network, NodeMailbox};
use crate::dataflow::task::{NodeId, TaskClass, TaskDesc};
use crate::dataflow::ttg::TaskGraph;
use crate::dataflow::ActivationTracker;
use crate::faults::{FaultMark, FaultPlan};
use crate::metrics::{NodeReport, PollSample, RecoveryStats, RunReport};
use crate::migrate::{
    class_estimate_update, classify_reply, ewma_update, exec_estimate_seeded_us, is_starving,
    merge_estimate, protocol::decide_steal, steal_req_id, steal_timeout_us, suspicion_timeout_us,
    EstimateDigest, ExecSnapshot, MigrateConfig, StarvationView, StealStats, VictimOutcome,
    VictimSelect, VictimSelector, ACK_PROBE_BUDGET, THIEF_RETRY_BUDGET,
};
use crate::sched::{BatchSite, POOL_FLOOR, SchedBackend, Scheduler, StealOutcome, TaskMeta};
use crate::term::{SafraAction, SafraState};
use crate::topology::{EscalationState, StealDomains, Topology, TIER_COUNT};
use crate::util::rng::{fault_rng, thief_rng};

/// Real-mode run configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClusterConfig {
    pub workers_per_node: usize,
    pub link: LinkModel,
    pub migrate: MigrateConfig,
    pub seed: u64,
    /// Record Fig.1/Fig.3 poll samples.
    pub record_polls: bool,
    /// Scheduler backend per node (`--sched central|sharded|workassist`).
    pub sched: SchedBackend,
    /// Coalesce same-destination successor activations into one
    /// `ActivateBatch` message (`--batch-activations`; off reproduces
    /// the per-edge protocol for ablations). Also routes each local
    /// activation ready set through one batched queue insert.
    pub batch_activations: bool,
    /// Sharded steal-pool floor (`--pool-floor`; see
    /// [`crate::sched::POOL_FLOOR`]).
    pub pool_floor: usize,
    /// Fault-injection plan (`--faults`) applied by the message fabric
    /// to steal traffic, plus the self-healing protocol it activates
    /// (request timeouts, retries, the victim-side transfer ledger).
    /// Disabled by default — the fabric and protocol are then
    /// byte-identical to the fault-free runtime.
    pub faults: FaultPlan,
    /// Tiered link model (`--topology`): the single source of per-pair
    /// link parameters for the wire model, the steal/suspicion timeout
    /// formulas and the victim selector's round-trip price. The flat
    /// default leaves every pair on `link`, byte-identical to the
    /// untiered runtime.
    pub topology: Topology,
    /// Steal-domain policy (`--steal-domains`): hierarchical thieves
    /// exhaust the nearest topology tier before escalating outward.
    pub steal_domains: StealDomains,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            workers_per_node: 4,
            link: LinkModel::ideal(),
            migrate: MigrateConfig::default(),
            seed: 1,
            record_polls: true,
            sched: SchedBackend::Central,
            batch_activations: true,
            pool_floor: POOL_FLOOR,
            faults: FaultPlan::default(),
            topology: Topology::flat(),
            steal_domains: StealDomains::Flat,
        }
    }
}

/// Chainable setters: `ClusterConfig::default().with_seed(7)…` — the
/// builder face of the config, so call sites name only what they
/// change and new fields stop taxing every struct literal in the tree.
impl ClusterConfig {
    pub fn with_workers_per_node(mut self, v: usize) -> Self {
        self.workers_per_node = v;
        self
    }
    pub fn with_link(mut self, v: LinkModel) -> Self {
        self.link = v;
        self
    }
    pub fn with_migrate(mut self, v: MigrateConfig) -> Self {
        self.migrate = v;
        self
    }
    pub fn with_seed(mut self, v: u64) -> Self {
        self.seed = v;
        self
    }
    pub fn with_record_polls(mut self, v: bool) -> Self {
        self.record_polls = v;
        self
    }
    pub fn with_sched(mut self, v: SchedBackend) -> Self {
        self.sched = v;
        self
    }
    pub fn with_batch_activations(mut self, v: bool) -> Self {
        self.batch_activations = v;
        self
    }
    pub fn with_pool_floor(mut self, v: usize) -> Self {
        self.pool_floor = v;
        self
    }
    pub fn with_faults(mut self, v: FaultPlan) -> Self {
        self.faults = v;
        self
    }
    pub fn with_topology(mut self, v: Topology) -> Self {
        self.topology = v;
        self
    }
    pub fn with_steal_domains(mut self, v: StealDomains) -> Self {
        self.steal_domains = v;
        self
    }
}

/// One outstanding thief-side steal request. The map is maintained even
/// with `--faults` off: matching replies to requests is what lets the
/// shutdown drain reclaim the inflight slot of a reply that never got
/// processed (the pre-PR 7 `inflight_steals` leak).
#[derive(Clone, Copy, Debug)]
struct PendingSteal {
    victim: NodeId,
    sent_at: Instant,
    /// Retry number (0 = first try) — indexes the capped exponential
    /// backoff in [`steal_timeout_us`].
    attempt: u32,
}

/// Thief-side request bookkeeping, one mutex for both maps: the
/// comm thread's resolve (check `resolved`, remove `pending`, record
/// the outcome) and the migrate thread's timeout claim (remove
/// `pending`, mark Abandoned) must each be atomic against the other,
/// or a reply racing a timeout could both enqueue the tasks *and* nack
/// the victim into reclaiming them — a double execution.
#[derive(Default)]
struct StealBook {
    pending: HashMap<u64, PendingSteal>,
    resolved: HashMap<u64, StealResolution>,
}

/// Terminal state of a thief-side request (`--faults` only), kept so a
/// late or fabric-duplicated reply is suppressed instead of processed
/// twice, and so the victim's retransmits can be re-answered with the
/// ack they are waiting for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum StealResolution {
    /// A granted reply was accepted and its tasks enqueued; the ack
    /// went (or is being re-sent) to the victim.
    AckedGrant,
    /// A denial was processed — nothing to ack (the victim keeps no
    /// ledger entry for denials).
    AckedDenial,
    /// The thief timed out and nacked; any reply that still arrives is
    /// discarded and re-nacked so the victim reclaims exactly once.
    Abandoned,
}

/// Victim-side record of a granted-but-unacknowledged transfer
/// (`--faults` only). The tasks live here — off the queue, not yet
/// owned by the thief — until the thief's [`Msg::TransferAck`] retires
/// the entry (accepted) or reclaims it (nack → batch reinsert), so a
/// dropped reply can never lose tasks and a duplicated one can never
/// double them.
struct LedgerEntry {
    thief: NodeId,
    /// The granted tasks, for the nack-reclaim reinsert.
    tasks: Vec<TaskDesc>,
    /// The exact reply message sent, retransmitted verbatim on
    /// ack-timeout and on fabric-duplicated requests.
    reply: Msg,
    sent_at: Instant,
    /// Retransmit number — backoff index, uncapped count (the victim
    /// never unilaterally reclaims; only a nack reclaims).
    attempt: u32,
}

/// Shared state of one runtime domain.
struct NodeState {
    id: NodeId,
    /// The ready queue; backends do their own locking (the sharded one
    /// is the whole point — see [`crate::sched`]).
    queue: Box<dyn Scheduler>,
    /// Pairs with `queue_cv` for idle-worker parking: the queue locks
    /// internally now, so the wait needs its own mutex.
    idle: Mutex<()>,
    queue_cv: Condvar,
    /// Workers currently parked (or about to park) on `queue_cv`.
    /// `enqueue` skips the lock+notify entirely while this is zero, so
    /// the insert hot path stays lock-free node-wide under load.
    parked: AtomicUsize,
    tracker: Mutex<ActivationTracker>,
    executing_count: AtomicUsize,
    /// Local successors of tasks currently executing — the "future
    /// tasks" of the thief policy, maintained incrementally (added at
    /// execution start, subtracted at finish) so the starvation poll is
    /// an O(1) read instead of a walk over the executing set.
    executing_local_succ: AtomicUsize,
    tasks_done: AtomicU64,
    exec_sum_ns: AtomicU64,
    /// EWMA of observed execution times (µs), stored as `f64` bits —
    /// updated at task finish when `MigrateConfig::exec_ewma` is on,
    /// read by the victim-side waiting-time gate. 0 bits = 0.0 = no
    /// history yet.
    exec_ewma_us_bits: AtomicU64,
    /// Per-class execution-time estimates (µs as `f64` bits), updated
    /// at task finish when [`MigrateConfig::track_per_class`] via the
    /// shared [`class_estimate_update`] rule — the threaded twin of
    /// the DES's plain-field table. 0 bits = no history for the class.
    /// Under `--share-estimates`, steal-reply digests merge into the
    /// same cells through [`merge_estimate`] (CAS over the f64 bits).
    class_est_us_bits: [AtomicU64; TaskClass::COUNT],
    /// Completed-task counts behind each class estimate — the merge
    /// weights for `--share-estimates` (local finishes count 1 each,
    /// merged digests add the victim's sample count).
    class_samples: [AtomicU64; TaskClass::COUNT],
    /// Digest-merged node-wide estimate from past victims (µs as `f64`
    /// bits) and its sample weight: the cold-start fallback the gate
    /// uses before this node has finished a single task
    /// ([`exec_estimate_seeded_us`]).
    remote_avg_us_bits: AtomicU64,
    remote_avg_samples: AtomicU64,
    /// Steal-reply digests merged into this node's tables.
    digest_merges: AtomicU64,
    /// Class entries adopted cold from a digest (no local history).
    digest_class_adoptions: AtomicU64,
    /// Non-empty activation ready sets delivered through the batched
    /// path — the runtime-layer count the scheduler's activation-site
    /// batch counter is asserted against (exactly one batched insert
    /// per non-empty ready set).
    activation_ready_batches: AtomicU64,
    busy_ns: AtomicU64,
    steal: Mutex<StealStats>,
    /// Thief-side per-victim reply outcomes (index = victim node):
    /// granted / waiting-time-denied / empty, recorded for every reply
    /// regardless of `--victim-select` so the targeted-vs-uniform
    /// ablation is observable without a debugger.
    victim_grants: Vec<AtomicU64>,
    victim_wt_denials: Vec<AtomicU64>,
    victim_empties: Vec<AtomicU64>,
    /// Thief-side steal timeouts per victim (`--faults`), the fourth
    /// outcome column of the per-victim telemetry.
    victim_timeouts: Vec<AtomicU64>,
    /// Victims permanently quarantined by this node (`--faults`): a
    /// crashed peer declared by membership, or one whose retry budget
    /// ran dry without a single answered request. At most 1 per victim
    /// — all quarantine sites go through the same guarded helper.
    victim_quarantined: Vec<AtomicU64>,
    /// The targeted victim selector (`--victim-select targeted`):
    /// picked by the migrate thread, fed replies by the comm thread.
    /// Uniform mode never takes this lock.
    victim_sel: Mutex<VictimSelector>,
    /// Hierarchical steal-domain escalation (`--steal-domains
    /// hierarchical`): the migrate thread reads the current tier when
    /// choosing a victim, the comm thread resets/widens it on reply
    /// outcomes. Flat mode never takes this lock.
    escalation: Mutex<EscalationState>,
    /// Thief-side steal traffic by topology tier of the victim:
    /// requests sent (including retries), granted replies, and granted
    /// reply wire bytes. On a flat topology everything lands in the
    /// cluster tier.
    tier_steal_requests: [AtomicU64; TIER_COUNT],
    tier_steal_grants: [AtomicU64; TIER_COUNT],
    tier_steal_bytes: [AtomicU64; TIER_COUNT],
    /// Per-class ready-queue population, maintained incrementally
    /// (increment before the queue insert, decrement after the pop, so
    /// the count never transiently underflows): the thief-side class
    /// mix the targeted selector weighs digests against.
    queued_class: [AtomicU64; TaskClass::COUNT],
    inflight_steals: AtomicUsize,
    /// Monotone request-id counter for [`steal_req_id`].
    next_req: AtomicU64,
    /// Outstanding thief-side requests (always maintained — see
    /// [`PendingSteal`]) and their terminal resolutions (`--faults`
    /// only), under one lock (see [`StealBook`]).
    steal_book: Mutex<StealBook>,
    /// Victim-side request ids already served (`--faults` only):
    /// fabric-duplicated requests re-answer from the ledger instead of
    /// extracting twice.
    served_reqs: Mutex<HashSet<u64>>,
    /// Victim-side transfer ledger (`--faults` only).
    ledger: Mutex<HashMap<u64, LedgerEntry>>,
    /// Tasks parked in the ledger — a node holding unacked transfers is
    /// not passive (Safra safety: those tasks are nowhere else).
    ledger_tasks: AtomicUsize,
    /// `--faults` protocol telemetry (see [`NodeReport`]).
    steal_timeouts: AtomicU64,
    steal_retries: AtomicU64,
    ledger_reclaims: AtomicU64,
    dup_replies_suppressed: AtomicU64,
    safra: Mutex<SafraState>,
    shutdown: AtomicBool,
    /// This node crash-stopped (`--faults crash-*`). Flipped under the
    /// `alive_gate` write lock, so every finish that began while the
    /// node was alive completes all its sends before the fabric gate
    /// arms — a counted task can never lose part of its fan-out.
    crashed: AtomicBool,
    /// Crash boundary: workers hold the read side across the finish
    /// path (count + activation sends); the crash takes the write side
    /// to flip `crashed`, so no finish is ever torn by the crash.
    alive_gate: RwLock<()>,
    /// Tasks a worker had popped (or finished un-counted) when the
    /// crash hit: lineage recovery re-homes them to the rehash
    /// survivor together with the dead queue.
    orphaned: Mutex<Vec<TaskDesc>>,
    polls: Mutex<Vec<PollSample>>,
    arrival_ready: Mutex<Vec<PollSample>>,
    /// ns-since-start of the last task completion (makespan).
    last_finish_ns: AtomicU64,
}

impl NodeState {
    fn passive(&self) -> bool {
        self.executing_count.load(Ordering::SeqCst) == 0
            && self.queue.is_empty()
            // Unacked granted transfers: the tasks exist only in this
            // node's ledger, so the node must stay active until the
            // thief's ack retires them or its nack reclaims them.
            && self.ledger_tasks.load(Ordering::SeqCst) == 0
    }
}

/// The in-process cluster. Build with [`Cluster::run`] — it owns the
/// whole lifecycle (spawn, execute, detect termination, join, report).
pub struct Cluster;

/// Crash-stop membership and recovery bookkeeping, shared by every
/// thread of every node (`--faults crash-*`; all-zero / all-alive when
/// no crash is scheduled, and then never written).
struct Recovery {
    /// The crash schedule, resolved once at startup from the fault
    /// plan's dedicated RNG stream — the same draw the DES makes, so
    /// both runtimes agree on who dies and when. Node 0 (ring leader,
    /// recovery coordinator) is never in here by construction.
    crash: Option<(u32, f64)>,
    /// Leader-maintained membership: flipped false (then `epoch`
    /// bumped) when the failure detector confirms a crash. Every comm
    /// thread mirrors epoch changes into its own Safra ring and victim
    /// quarantine.
    alive: Vec<AtomicBool>,
    epoch: AtomicU64,
    nodes_suspected: AtomicU64,
    nodes_crashed: AtomicU64,
    tasks_recovered: AtomicU64,
    ring_repairs: AtomicU64,
    detect_latency_us_bits: AtomicU64,
}

struct Shared {
    graph: Arc<dyn TaskGraph>,
    net: Arc<Network>,
    nodes: Vec<Arc<NodeState>>,
    cfg: ClusterConfig,
    start: Instant,
    recovery: Recovery,
}

impl Cluster {
    /// Execute `graph` with `executor` task bodies; blocks until
    /// distributed termination and returns the merged report.
    pub fn run(
        graph: Arc<dyn TaskGraph>,
        cfg: ClusterConfig,
        executor: Arc<dyn super::TaskExecutor>,
    ) -> RunReport {
        let n = graph.num_nodes();
        let (net, mailboxes) =
            Network::new_with_topology(n, cfg.link, cfg.topology, cfg.faults, cfg.seed);
        let nodes: Vec<Arc<NodeState>> = (0..n)
            .map(|i| {
                Arc::new(NodeState {
                    id: NodeId(i as u32),
                    queue: cfg.sched.build_with(cfg.workers_per_node, cfg.pool_floor),
                    idle: Mutex::new(()),
                    queue_cv: Condvar::new(),
                    parked: AtomicUsize::new(0),
                    tracker: Mutex::new(ActivationTracker::new()),
                    executing_count: AtomicUsize::new(0),
                    executing_local_succ: AtomicUsize::new(0),
                    tasks_done: AtomicU64::new(0),
                    exec_sum_ns: AtomicU64::new(0),
                    exec_ewma_us_bits: AtomicU64::new(0),
                    class_est_us_bits: std::array::from_fn(|_| AtomicU64::new(0)),
                    class_samples: std::array::from_fn(|_| AtomicU64::new(0)),
                    remote_avg_us_bits: AtomicU64::new(0),
                    remote_avg_samples: AtomicU64::new(0),
                    digest_merges: AtomicU64::new(0),
                    digest_class_adoptions: AtomicU64::new(0),
                    activation_ready_batches: AtomicU64::new(0),
                    busy_ns: AtomicU64::new(0),
                    steal: Mutex::new(StealStats::default()),
                    victim_grants: (0..n).map(|_| AtomicU64::new(0)).collect(),
                    victim_wt_denials: (0..n).map(|_| AtomicU64::new(0)).collect(),
                    victim_empties: (0..n).map(|_| AtomicU64::new(0)).collect(),
                    victim_timeouts: (0..n).map(|_| AtomicU64::new(0)).collect(),
                    victim_quarantined: (0..n).map(|_| AtomicU64::new(0)).collect(),
                    victim_sel: Mutex::new(
                        VictimSelector::new(i, n.max(2), thief_rng(cfg.seed, i))
                            .with_topology(&cfg.topology, cfg.link),
                    ),
                    escalation: Mutex::new(EscalationState::new(&cfg.topology, i, n)),
                    tier_steal_requests: std::array::from_fn(|_| AtomicU64::new(0)),
                    tier_steal_grants: std::array::from_fn(|_| AtomicU64::new(0)),
                    tier_steal_bytes: std::array::from_fn(|_| AtomicU64::new(0)),
                    queued_class: std::array::from_fn(|_| AtomicU64::new(0)),
                    inflight_steals: AtomicUsize::new(0),
                    next_req: AtomicU64::new(0),
                    steal_book: Mutex::new(StealBook::default()),
                    served_reqs: Mutex::new(HashSet::new()),
                    ledger: Mutex::new(HashMap::new()),
                    ledger_tasks: AtomicUsize::new(0),
                    steal_timeouts: AtomicU64::new(0),
                    steal_retries: AtomicU64::new(0),
                    ledger_reclaims: AtomicU64::new(0),
                    dup_replies_suppressed: AtomicU64::new(0),
                    safra: Mutex::new(SafraState::new(NodeId(i as u32), n)),
                    shutdown: AtomicBool::new(false),
                    crashed: AtomicBool::new(false),
                    alive_gate: RwLock::new(()),
                    orphaned: Mutex::new(Vec::new()),
                    polls: Mutex::new(Vec::new()),
                    arrival_ready: Mutex::new(Vec::new()),
                    last_finish_ns: AtomicU64::new(0),
                })
            })
            .collect();

        // The same dedicated RNG stream the DES uses, so both runtimes
        // agree on who dies and when (zero draws when no crash spec).
        let crash = cfg
            .faults
            .crash_schedule(n, &mut fault_rng(cfg.seed, 1));
        let shared = Arc::new(Shared {
            graph: graph.clone(),
            net: net.clone(),
            nodes: nodes.clone(),
            cfg,
            start: Instant::now(),
            recovery: Recovery {
                crash,
                alive: (0..n).map(|_| AtomicBool::new(true)).collect(),
                epoch: AtomicU64::new(0),
                nodes_suspected: AtomicU64::new(0),
                nodes_crashed: AtomicU64::new(0),
                tasks_recovered: AtomicU64::new(0),
                ring_repairs: AtomicU64::new(0),
                detect_latency_us_bits: AtomicU64::new(0),
            },
        });

        // Seed roots at their owners.
        for root in graph.roots() {
            let owner = graph.owner(root);
            let node = &nodes[owner.idx()];
            node.tracker.lock().unwrap().mark_root(root);
            enqueue(node, graph.as_ref(), root);
        }

        let mut handles = Vec::new();
        let mut boxes = mailboxes;
        // drain in reverse so indices stay valid
        let mut mail: Vec<Option<NodeMailbox>> = boxes.drain(..).map(Some).collect();
        for i in 0..n {
            let node = nodes[i].clone();
            let sh = shared.clone();
            let mb = mail[i].take().unwrap();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("comm-{i}"))
                    .spawn(move || comm_loop(sh, node, mb))
                    .unwrap(),
            );
            for w in 0..cfg.workers_per_node {
                let node = nodes[i].clone();
                let sh = shared.clone();
                let ex = executor.clone();
                handles.push(
                    std::thread::Builder::new()
                        .name(format!("worker-{i}.{w}"))
                        .spawn(move || worker_loop(sh, node, w, ex))
                        .unwrap(),
                );
            }
            if cfg.migrate.enabled && n > 1 {
                let node = nodes[i].clone();
                let sh = shared.clone();
                handles.push(
                    std::thread::Builder::new()
                        .name(format!("migrate-{i}"))
                        .spawn(move || migrate_loop(sh, node))
                        .unwrap(),
                );
            }
        }

        for h in handles {
            let _ = h.join();
        }
        net.shutdown();

        // Self-healing postconditions. Requests still pending at
        // shutdown (their reply sat undelivered in a mailbox, or was
        // dropped by the fault plan) are abandoned now, reclaiming
        // their inflight slots — then every slot must be accounted for
        // and the transfer ledger empty: exactly-once conservation has
        // no residue under any fault pattern.
        for nd in &nodes {
            let abandoned = nd.steal_book.lock().unwrap().pending.drain().count();
            if abandoned > 0 {
                nd.inflight_steals.fetch_sub(abandoned, Ordering::SeqCst);
            }
            assert_eq!(
                nd.inflight_steals.load(Ordering::SeqCst),
                0,
                "node {} leaked inflight-steal slots",
                nd.id.0
            );
            assert!(
                nd.ledger.lock().unwrap().is_empty(),
                "node {} shut down with transfer-ledger residue",
                nd.id.0
            );
            assert_eq!(nd.ledger_tasks.load(Ordering::SeqCst), 0);
        }

        let makespan_ns = nodes
            .iter()
            .map(|nd| nd.last_finish_ns.load(Ordering::SeqCst))
            .max()
            .unwrap_or(0);

        let executed: u64 = nodes
            .iter()
            .map(|nd| nd.tasks_done.load(Ordering::SeqCst))
            .sum();
        if let Some(total) = graph.total_tasks() {
            assert_eq!(executed, total, "cluster lost tasks");
        }

        RunReport {
            workload: graph.name().to_string(),
            makespan_us: makespan_ns as f64 / 1e3,
            total_tasks: executed,
            workers_per_node: cfg.workers_per_node,
            link: cfg.link,
            events: 0,
            deliver_events: 0,
            faults_dropped: net.faults_dropped.load(Ordering::Relaxed),
            faults_duplicated: net.faults_duplicated.load(Ordering::Relaxed),
            recovery: RecoveryStats {
                nodes_suspected: shared.recovery.nodes_suspected.load(Ordering::SeqCst),
                nodes_crashed: shared.recovery.nodes_crashed.load(Ordering::SeqCst),
                tasks_recovered: shared.recovery.tasks_recovered.load(Ordering::SeqCst),
                ring_repairs: shared.recovery.ring_repairs.load(Ordering::SeqCst),
                detect_latency_us: f64::from_bits(
                    shared.recovery.detect_latency_us_bits.load(Ordering::SeqCst),
                ),
            },
            nodes: nodes
                .iter()
                .map(|nd| {
                    let done = nd.tasks_done.load(Ordering::SeqCst);
                    let sum_ns = nd.exec_sum_ns.load(Ordering::SeqCst);
                    NodeReport {
                        tasks_executed: done,
                        busy_us: nd.busy_ns.load(Ordering::SeqCst) as f64 / 1e3,
                        avg_exec_us: if done > 0 {
                            sum_ns as f64 / done as f64 / 1e3
                        } else {
                            0.0
                        },
                        class_est_us: std::array::from_fn(|c| {
                            f64::from_bits(nd.class_est_us_bits[c].load(Ordering::Relaxed))
                        }),
                        digest_merges: nd.digest_merges.load(Ordering::Relaxed),
                        digest_class_adoptions: nd.digest_class_adoptions.load(Ordering::Relaxed),
                        activation_ready_batches: nd
                            .activation_ready_batches
                            .load(Ordering::Relaxed),
                        steal: *nd.steal.lock().unwrap(),
                        victim_grants: nd
                            .victim_grants
                            .iter()
                            .map(|a| a.load(Ordering::Relaxed))
                            .collect(),
                        victim_wt_denials: nd
                            .victim_wt_denials
                            .iter()
                            .map(|a| a.load(Ordering::Relaxed))
                            .collect(),
                        victim_empties: nd
                            .victim_empties
                            .iter()
                            .map(|a| a.load(Ordering::Relaxed))
                            .collect(),
                        victim_timeouts: nd
                            .victim_timeouts
                            .iter()
                            .map(|a| a.load(Ordering::Relaxed))
                            .collect(),
                        victim_quarantined: nd
                            .victim_quarantined
                            .iter()
                            .map(|a| a.load(Ordering::Relaxed))
                            .collect(),
                        tier_steal_requests: std::array::from_fn(|t| {
                            nd.tier_steal_requests[t].load(Ordering::Relaxed)
                        }),
                        tier_steal_grants: std::array::from_fn(|t| {
                            nd.tier_steal_grants[t].load(Ordering::Relaxed)
                        }),
                        tier_steal_bytes: std::array::from_fn(|t| {
                            nd.tier_steal_bytes[t].load(Ordering::Relaxed)
                        }),
                        steal_timeouts: nd.steal_timeouts.load(Ordering::Relaxed),
                        steal_retries: nd.steal_retries.load(Ordering::Relaxed),
                        ledger_reclaims: nd.ledger_reclaims.load(Ordering::Relaxed),
                        dup_replies_suppressed: nd
                            .dup_replies_suppressed
                            .load(Ordering::Relaxed),
                        sched: nd.queue.stats(),
                        polls: std::mem::take(&mut nd.polls.lock().unwrap()),
                        arrival_ready: std::mem::take(&mut nd.arrival_ready.lock().unwrap()),
                    }
                })
                .collect(),
        }
    }
}

/// Insert a ready task (with its steal-accounting meta) and wake a
/// worker.
fn enqueue(node: &NodeState, graph: &dyn TaskGraph, task: TaskDesc) {
    node.queued_class[task.class.idx()].fetch_add(1, Ordering::Relaxed);
    node.queue
        .insert_meta(task, graph.priority(task), TaskMeta::of(graph, task));
    // Only touch the idle lock when someone is (about to be) parked.
    // SeqCst pairing with the worker makes this sound: the worker
    // bumps `parked` before re-checking emptiness, we insert before
    // reading `parked` — one of the two always observes the other.
    if node.parked.load(Ordering::SeqCst) > 0 {
        // The lock orders us against a worker between its emptiness
        // re-check and its wait, so the notify cannot fall in the gap.
        let _idle = node.idle.lock().unwrap();
        node.queue_cv.notify_one();
    }
}

/// Insert a batch of ready tasks under one queue-lock acquisition
/// (booked to `site` — steal-reply re-enqueue or activation ready set),
/// then wake workers. Mirrors [`enqueue`], including the parked-worker
/// SeqCst protocol; `notify_all` because a batch can feed several
/// parked workers at once.
fn enqueue_batch(node: &NodeState, graph: &dyn TaskGraph, tasks: &[TaskDesc], site: BatchSite) {
    for t in tasks {
        node.queued_class[t.class.idx()].fetch_add(1, Ordering::Relaxed);
    }
    node.queue
        .insert_batch_at(site, &TaskMeta::batch_of(graph, tasks));
    if node.parked.load(Ordering::SeqCst) > 0 {
        let _idle = node.idle.lock().unwrap();
        node.queue_cv.notify_all();
    }
}

/// Release one task's slot in the per-class ready-queue census (the
/// pop-side twin of the `enqueue`/`enqueue_batch` increments).
/// Saturating: the census feeds a scoring heuristic, so a transient
/// accounting slip must never wrap the counter.
fn class_dec(node: &NodeState, class: TaskClass) {
    let _ = node.queued_class[class.idx()].fetch_update(
        Ordering::Relaxed,
        Ordering::Relaxed,
        |v| Some(v.saturating_sub(1)),
    );
}

/// Deliver one local activation; enqueue if it completed the in-degree.
fn activate_local(node: &NodeState, graph: &dyn TaskGraph, task: TaskDesc) {
    let ready = node.tracker.lock().unwrap().activate(graph, task);
    if ready {
        enqueue(node, graph, task);
    }
}

/// Deliver a coalesced activation batch under a single tracker lock,
/// then enqueue the whole ready set through one batched queue insert —
/// the batch-first activation pipeline: one tracker lock and one
/// queue-lock acquisition per delivery, however many tasks became
/// ready.
fn activate_local_batch(node: &NodeState, graph: &dyn TaskGraph, tasks: &[TaskDesc]) {
    let mut ready = Vec::new();
    {
        let mut tracker = node.tracker.lock().unwrap();
        for &t in tasks {
            if tracker.activate(graph, t) {
                ready.push(t);
            }
        }
    }
    if !ready.is_empty() {
        node.activation_ready_batches.fetch_add(1, Ordering::Relaxed);
        enqueue_batch(node, graph, &ready, BatchSite::Activation);
    }
}

/// Snapshot this node's execution-time knowledge for a granted steal
/// reply (`--share-estimates`): the node-wide estimate the gate just
/// ran on, plus the per-class table and its sample weights — handed to
/// the shared sample-capping [`EstimateDigest::snapshot`] constructor.
fn steal_digest(node: &NodeState, avg_us: f64, avg_samples: u64) -> EstimateDigest {
    EstimateDigest::snapshot(
        avg_us,
        avg_samples,
        std::array::from_fn(|c| {
            f64::from_bits(node.class_est_us_bits[c].load(Ordering::Relaxed))
        }),
        std::array::from_fn(|c| node.class_samples[c].load(Ordering::Relaxed)),
    )
}

/// Merge a steal-reply [`EstimateDigest`] into this node's estimator
/// tables (`--share-estimates`): the atomic twin of the shared
/// [`EstimateDigest::merge_into`] loop — per seeded class entry one CAS
/// loop over the f64-bits cell through the same [`merge_estimate`] rule
/// (the scheme `class_estimate_update` uses at task finish), plus the
/// node-wide cold-start seed. The sample-count read and the estimate
/// CAS are two operations, so a concurrent task finish can interleave —
/// the blend weight is then off by that one in-flight sample, which
/// only nudges a heuristic; counts and estimates both stay
/// monotone-consistent.
fn merge_digest(node: &NodeState, digest: &EstimateDigest) {
    let mut adoptions = 0u64;
    for c in 0..TaskClass::COUNT {
        let (remote_us, remote_n) = (digest.class_est_us[c], digest.class_samples[c]);
        if remote_n == 0 || remote_us <= 0.0 {
            continue; // unseeded at the victim: nothing to learn
        }
        let local_n = node.class_samples[c].load(Ordering::Relaxed);
        let mut adopted = false;
        let _ = node.class_est_us_bits[c].fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |bits| {
                let local_us = f64::from_bits(bits);
                adopted = !(local_n > 0 && local_us > 0.0);
                let (merged, _) = merge_estimate(local_us, local_n, remote_us, remote_n);
                Some(merged.to_bits())
            },
        );
        node.class_samples[c].fetch_add(remote_n, Ordering::Relaxed);
        adoptions += adopted as u64;
    }
    if digest.avg_samples > 0 && digest.avg_us > 0.0 {
        let local_n = node.remote_avg_samples.load(Ordering::Relaxed);
        let _ = node.remote_avg_us_bits.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |bits| {
                let (merged, _) = merge_estimate(
                    f64::from_bits(bits),
                    local_n,
                    digest.avg_us,
                    digest.avg_samples,
                );
                Some(merged.to_bits())
            },
        );
        node.remote_avg_samples
            .fetch_add(digest.avg_samples, Ordering::Relaxed);
    }
    node.digest_merges.fetch_add(1, Ordering::Relaxed);
    node.digest_class_adoptions
        .fetch_add(adoptions, Ordering::Relaxed);
}

/// Deterministic rehash target for a dead node's work: the first live
/// node cyclically after it — the same rule the DES uses, so both
/// runtimes re-home to the same survivor.
fn route_from(sh: &Shared, dead: usize) -> NodeId {
    let n = sh.nodes.len();
    for k in 1..n {
        let cand = (dead + k) % n;
        if sh.recovery.alive[cand].load(Ordering::SeqCst) {
            return NodeId(cand as u32);
        }
    }
    NodeId(0)
}

/// Permanently quarantine `victim` in this node's selector (guarded:
/// every quarantine site funnels here, so the per-victim telemetry
/// counts each victim at most once per thief).
fn quarantine_victim(node: &NodeState, victim: usize) {
    if victim == node.id.idx() {
        return;
    }
    let mut sel = node.victim_sel.lock().unwrap();
    if !sel.is_quarantined(victim) {
        sel.record(victim, VictimOutcome::Quarantined, None);
        node.victim_quarantined[victim].fetch_add(1, Ordering::Relaxed);
    }
}

/// Mirror the leader's membership into this node's local structures:
/// splice dead peers out of the Safra ring (discarding any held token;
/// per-peer deficits reconcile retroactively) and quarantine them in
/// the victim selector. Called by every comm thread when the epoch
/// moves — idempotent per peer.
fn sync_membership(sh: &Shared, node: &NodeState) {
    for p in 0..sh.nodes.len() {
        if sh.recovery.alive[p].load(Ordering::SeqCst) {
            continue;
        }
        let peer = NodeId(p as u32);
        {
            let mut safra = node.safra.lock().unwrap();
            if safra.is_live(peer) {
                safra.declare_dead(peer);
            }
        }
        quarantine_victim(node, p);
    }
}

/// Crash-stop this node (its own comm thread, at the scheduled
/// instant). Ordering is the whole point: flip `crashed` under the
/// `alive_gate` write lock first — the lock waits out every in-flight
/// finish, so no task is ever counted with part of its activation
/// fan-out unsent — and only then arm the fabric gate and bury the
/// mailbox backlog.
fn crash_self(sh: &Shared, node: &NodeState, mailbox: &NodeMailbox) {
    {
        let _gate = node.alive_gate.write().unwrap();
        node.crashed.store(true, Ordering::SeqCst);
    }
    sh.net.arm_crash(node.id.0, sh.net.now_us());
    while let Some(env) = mailbox.try_recv() {
        sh.net.bury(env);
    }
    // Wake parked workers so they observe the crash and exit.
    {
        let _idle = node.idle.lock().unwrap();
        node.queue_cv.notify_all();
    }
}

/// Leader-side confirmation of a crash: count it, flip membership,
/// bump the epoch (every comm thread syncs), repair the leader's own
/// ring immediately, then run the lineage recovery sweep.
fn leader_confirm_crash(sh: &Arc<Shared>, leader: &Arc<NodeState>, dead: usize, at_us: f64) {
    sh.recovery.nodes_crashed.fetch_add(1, Ordering::SeqCst);
    let latency_us = (sh.net.now_us() - at_us).max(f64::MIN_POSITIVE);
    sh.recovery
        .detect_latency_us_bits
        .store(latency_us.to_bits(), Ordering::SeqCst);
    sh.recovery.alive[dead].store(false, Ordering::SeqCst);
    sh.recovery.epoch.fetch_add(1, Ordering::SeqCst);
    sh.recovery.ring_repairs.fetch_add(1, Ordering::SeqCst);
    sync_membership(sh, leader);
    recovery_sweep(sh, leader, dead);
}

/// Lineage-based recovery of a dead node's unfinished work (leader,
/// once per crash, after the membership flip). Everything the dead
/// node still owed the computation is re-homed to the deterministic
/// rehash survivor [`route_from`]:
///
/// 1. its transfer ledger — each parked grant is settled against the
///    live thief's resolution book (acked ⇒ the thief owns the tasks;
///    otherwise they are marked Abandoned there, atomically, so a
///    still-in-flight reply can never double them, and re-homed);
/// 2. live victims' ledger entries granted *to* the dead thief —
///    settled against the dead node's book the same way (acked ⇒ the
///    tasks are in the dead queue and swept below; otherwise the
///    victim reclaims them);
/// 3. its ready queue and orphan bin, re-injected as one counted
///    [`Msg::Recover`] batch (dependencies were satisfied at the dead
///    node, so they bypass the survivor's tracker);
/// 4. its partially-activated tasks, replayed as counted activations
///    at the survivor (lazy in-degree init reproduces the dependency
///    state exactly);
/// 5. the fabric graveyard — buried activations re-sent (counted) to
///    their rerouted destinations; steal-protocol traffic is dropped,
///    that protocol heals itself.
fn recovery_sweep(sh: &Arc<Shared>, leader: &Arc<NodeState>, dead: usize) {
    let graph = sh.graph.as_ref();
    let dn = &sh.nodes[dead];
    // The dead node's own comm thread released this write lock at the
    // crash instant; taking it again orders the sweep after any
    // straggling finish.
    let _gate = dn.alive_gate.write().unwrap();

    let mut ready: Vec<TaskDesc> = Vec::new();

    // (1) The dead node's own ledger: grants parked for live thieves.
    let mut parked: Vec<(u64, LedgerEntry)> = dn.ledger.lock().unwrap().drain().collect();
    parked.sort_unstable_by_key(|(req, _)| *req);
    for (req, e) in parked {
        dn.ledger_tasks.fetch_sub(e.tasks.len(), Ordering::SeqCst);
        let thief = &sh.nodes[e.thief.idx()];
        let settled = {
            let mut book = thief.steal_book.lock().unwrap();
            match book.resolved.get(&req).copied() {
                Some(r) => r,
                None => {
                    // Unresolved at the thief: abandon it there, in
                    // the same critical section, so a late reply is
                    // suppressed instead of enqueued a second time.
                    if book.pending.remove(&req).is_some() {
                        thief.inflight_steals.fetch_sub(1, Ordering::SeqCst);
                    }
                    book.resolved.insert(req, StealResolution::Abandoned);
                    StealResolution::Abandoned
                }
            }
        };
        if settled != StealResolution::AckedGrant {
            ready.extend(e.tasks);
        }
    }

    // (2) Live victims' ledgers: grants parked for the dead thief.
    for nd in &sh.nodes {
        if nd.id.idx() == dead {
            continue;
        }
        let mut gone: Vec<(u64, LedgerEntry)> = {
            let mut ledger = nd.ledger.lock().unwrap();
            let reqs: Vec<u64> = ledger
                .iter()
                .filter(|(_, e)| e.thief.idx() == dead)
                .map(|(&req, _)| req)
                .collect();
            reqs.into_iter()
                .map(|req| (req, ledger.remove(&req).unwrap()))
                .collect()
        };
        gone.sort_unstable_by_key(|(req, _)| *req);
        for (req, e) in gone {
            nd.ledger_tasks.fetch_sub(e.tasks.len(), Ordering::SeqCst);
            let settled = dn.steal_book.lock().unwrap().resolved.get(&req).copied();
            if settled == Some(StealResolution::AckedGrant) {
                // The dead thief had accepted: the tasks are in its
                // queue (or were executed) — covered by the sweep
                // below, the entry just retires.
                continue;
            }
            nd.ledger_reclaims.fetch_add(1, Ordering::Relaxed);
            enqueue_batch(nd, graph, &e.tasks, BatchSite::GateDenial);
        }
    }

    // (3) The dead ready queue and the workers' orphan bin.
    let drained = dn.queue.drain();
    for t in &drained {
        class_dec(dn, t.class);
    }
    ready.extend(drained);
    ready.extend(dn.orphaned.lock().unwrap().drain(..));
    ready.sort_unstable();

    // (4) Partially-activated lineage.
    let partial = dn.tracker.lock().unwrap().drain_partial(graph);

    sh.recovery
        .tasks_recovered
        .fetch_add((ready.len() + partial.len()) as u64, Ordering::SeqCst);

    let target = route_from(sh, dead);
    if !ready.is_empty() {
        if target == leader.id {
            enqueue_batch(leader, graph, &ready, BatchSite::Other);
        } else {
            leader.safra.lock().unwrap().on_send(target);
            sh.net.send(leader.id, target, Msg::Recover { tasks: ready });
        }
    }
    if !partial.is_empty() {
        let mut replay: Vec<TaskDesc> = Vec::new();
        for (t, satisfied) in partial {
            for _ in 0..satisfied {
                replay.push(t);
            }
        }
        if target == leader.id {
            activate_local_batch(leader, graph, &replay);
        } else {
            leader.safra.lock().unwrap().on_send(target);
            sh.net
                .send(leader.id, target, Msg::ActivateBatch { tasks: replay });
        }
    }

    // (5) Buried traffic.
    reinject_graveyard(sh, leader);
}

/// Drain the fabric graveyard and re-inject what still matters:
/// activations and recovery batches are re-sent — counted, rerouted to
/// the rehash survivor if addressed to the dead — while steal-protocol
/// traffic is dropped (timeouts, retries and the ledger heal that
/// path) and control traffic simply dies. The original sends were
/// spliced out of the Safra deficit by `declare_dead`, so the counted
/// re-sends keep termination accounting exact.
fn reinject_graveyard(sh: &Arc<Shared>, node: &Arc<NodeState>) {
    let graph = sh.graph.as_ref();
    for env in sh.net.drain_graveyard() {
        if env.fault == FaultMark::Dropped {
            continue; // the plan had already sentenced this copy
        }
        match env.msg {
            Msg::Activate { .. } | Msg::ActivateBatch { .. } | Msg::Recover { .. } => {
                let dst = if sh.recovery.alive[env.dst.idx()].load(Ordering::SeqCst) {
                    env.dst
                } else {
                    route_from(sh, env.dst.idx())
                };
                if dst == node.id {
                    match env.msg {
                        Msg::Activate { task } => activate_local(node, graph, task),
                        Msg::ActivateBatch { tasks } => activate_local_batch(node, graph, &tasks),
                        Msg::Recover { tasks } => {
                            enqueue_batch(node, graph, &tasks, BatchSite::Other)
                        }
                        _ => unreachable!(),
                    }
                } else {
                    node.safra.lock().unwrap().on_send(dst);
                    sh.net.send(node.id, dst, env.msg);
                }
            }
            _ => {}
        }
    }
}

fn worker_loop(
    sh: Arc<Shared>,
    node: Arc<NodeState>,
    worker: usize,
    ex: Arc<dyn super::TaskExecutor>,
) {
    let graph = sh.graph.as_ref();
    // Only the scheduled crash victim ever pays for the alive-gate
    // read lock on its finish path (uncontended until the crash).
    let crash_scheduled = sh.recovery.crash.is_some();
    let crash_victim = sh.recovery.crash.is_some_and(|(c, _)| c == node.id.0);
    loop {
        if node.shutdown.load(Ordering::SeqCst) || node.crashed.load(Ordering::SeqCst) {
            return;
        }
        // Claim execution intent BEFORE popping: from the instant a
        // task leaves the queue until it is accounted as executing, the
        // node must never look passive — otherwise a Safra token round
        // could declare termination with the task in flight.
        node.executing_count.fetch_add(1, Ordering::SeqCst);
        // select (worker index = shard hint for the sharded backend)
        let Some(task) = node.queue.select(worker) else {
            node.executing_count.fetch_sub(1, Ordering::SeqCst);
            let idle = node.idle.lock().unwrap();
            // Declare ourselves parked BEFORE re-checking emptiness:
            // `enqueue` reads the counter after its insert, so either
            // it sees us parked (and notifies) or we see its task
            // (and skip the wait). The timeout is belt-and-braces.
            node.parked.fetch_add(1, Ordering::SeqCst);
            if node.queue.is_empty() && !node.shutdown.load(Ordering::SeqCst) {
                let _unused = node
                    .queue_cv
                    .wait_timeout(idle, Duration::from_micros(200))
                    .unwrap();
            }
            node.parked.fetch_sub(1, Ordering::SeqCst);
            continue;
        };
        class_dec(&node, task.class);
        if node.crashed.load(Ordering::SeqCst) {
            // Crash-stopped between the pop and the execution: the
            // task dies with the node — into the orphan bin, where the
            // lineage sweep re-homes it to the rehash survivor.
            node.executing_count.fetch_sub(1, Ordering::SeqCst);
            node.orphaned.lock().unwrap().push(task);
            return;
        }
        if sh.cfg.record_polls {
            let sample = PollSample {
                t_us: sh.start.elapsed().as_nanos() as f64 / 1e3,
                ready: node.queue.len() as u32,
            };
            node.polls.lock().unwrap().push(sample);
        }

        // Successor derivation is a pure function of the descriptor, so
        // it can run before the body: the count feeds the O(1)
        // starvation view while the task executes, and the same vec
        // drives the activation fan-out afterwards.
        let succs = graph.successors(task);
        let dynamic = graph.dynamic_placement();
        let local_succ = succs
            .iter()
            .filter(|s| dynamic || graph.owner(**s) == node.id)
            .count();
        node.executing_local_succ
            .fetch_add(local_succ, Ordering::SeqCst);

        let t0 = Instant::now();
        ex.execute(node.id, task);
        let dur_ns = t0.elapsed().as_nanos() as u64;

        // Crash boundary: on the scheduled victim the whole finish
        // (activation fan-out + counters) runs under the alive-gate
        // read lock. The crash takes the write side before arming the
        // fabric, so a finish either completes every send while the
        // fabric is still up, or observes `crashed` here and orphans
        // the task — never a counted task with a half-buried fan-out.
        let _alive = if crash_victim {
            let gate = node.alive_gate.read().unwrap();
            if node.crashed.load(Ordering::SeqCst) {
                drop(gate);
                node.executing_local_succ
                    .fetch_sub(local_succ, Ordering::SeqCst);
                node.executing_count.fetch_sub(1, Ordering::SeqCst);
                node.orphaned.lock().unwrap().push(task);
                return;
            }
            Some(gate)
        } else {
            None
        };

        // Propagate activations BEFORE leaving the executing state so the
        // node is never "passive" with un-sent messages (Safra safety).
        // Remote successors sharing a destination coalesce into one
        // ActivateBatch message (one wire header, one Safra deficit
        // entry, one tracker lock at the receiver); local successors
        // coalesce the same way into one tracker lock + one batched
        // queue insert. `--batch-activations false` restores the
        // per-edge protocol on both paths for ablations.
        let mut local: Vec<TaskDesc> = Vec::new();
        let mut remote: Vec<(NodeId, Vec<TaskDesc>)> = Vec::new();
        for s in succs {
            let mut dest = if dynamic { node.id } else { graph.owner(s) };
            if crash_scheduled && !sh.recovery.alive[dest.idx()].load(Ordering::SeqCst) {
                // The owner was declared dead: lineage recovery
                // re-homed its tasks to the rehash survivor, so new
                // activations for them must follow.
                dest = route_from(&sh, dest.idx());
            }
            if dest == node.id {
                if sh.cfg.batch_activations {
                    local.push(s);
                } else {
                    activate_local(&node, graph, s);
                }
            } else if sh.cfg.batch_activations {
                match remote.iter_mut().find(|(d, _)| *d == dest) {
                    Some((_, bucket)) => bucket.push(s),
                    None => remote.push((dest, vec![s])),
                }
            } else {
                node.safra.lock().unwrap().on_send(dest);
                sh.net.send(node.id, dest, Msg::Activate { task: s });
            }
        }
        if !local.is_empty() {
            activate_local_batch(&node, graph, &local);
        }
        for (dest, tasks) in remote {
            node.safra.lock().unwrap().on_send(dest);
            let msg = if tasks.len() == 1 {
                Msg::Activate { task: tasks[0] }
            } else {
                Msg::ActivateBatch { tasks }
            };
            sh.net.send(node.id, dest, msg);
        }

        node.executing_local_succ
            .fetch_sub(local_succ, Ordering::SeqCst);
        node.executing_count.fetch_sub(1, Ordering::SeqCst);
        node.tasks_done.fetch_add(1, Ordering::SeqCst);
        node.exec_sum_ns.fetch_add(dur_ns, Ordering::SeqCst);
        if sh.cfg.migrate.exec_ewma {
            // CAS loop over the f64 bits: lock-free per-finish EWMA
            // update (contended only by the other workers' finishes).
            let dur_us = dur_ns as f64 / 1e3;
            let _ = node
                .exec_ewma_us_bits
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
                    Some(ewma_update(f64::from_bits(bits), dur_us).to_bits())
                });
        }
        if sh.cfg.migrate.track_per_class() {
            // Same CAS-over-bits scheme, one cell per class, through the
            // shared update rule so the DES table cannot diverge. Also
            // maintained under --share-estimates alone: a victim with an
            // empty table would have nothing worth shipping to thieves.
            let dur_us = dur_ns as f64 / 1e3;
            let cell = &node.class_est_us_bits[task.class.idx()];
            let _ = cell.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
                Some(class_estimate_update(f64::from_bits(bits), dur_us).to_bits())
            });
            node.class_samples[task.class.idx()].fetch_add(1, Ordering::Relaxed);
        }
        node.busy_ns.fetch_add(dur_ns, Ordering::SeqCst);
        node.last_finish_ns
            .fetch_max(sh.start.elapsed().as_nanos() as u64, Ordering::SeqCst);
    }
}

fn comm_loop(sh: Arc<Shared>, node: Arc<NodeState>, mailbox: NodeMailbox) {
    let graph = sh.graph.as_ref();
    let n = sh.nodes.len();
    let crash = sh.recovery.crash;
    // The detector must tolerate the slowest pair in the topology, or
    // a quiet node across the widest tier would be suspected by its
    // own heartbeat latency (worst_link is the base link when flat).
    let worst = sh.cfg.topology.worst_link(n, sh.cfg.link);
    let suspicion_us = suspicion_timeout_us(
        worst.latency_us,
        worst.bw_bytes_per_us,
        sh.cfg.migrate.migrate_overhead_us,
        sh.cfg.migrate.poll_interval_us,
    );
    let mut last_probe = Instant::now();
    let mut last_ping = Instant::now();
    let mut last_scan = Instant::now();
    // Leader-side failure detector state: when each peer was last
    // heard from (any envelope counts) and which are under suspicion.
    let mut last_heard: Vec<Instant> = vec![Instant::now(); n];
    let mut suspected = vec![false; n];
    let mut seen_epoch = 0u64;
    loop {
        if node.shutdown.load(Ordering::SeqCst) {
            return;
        }
        if let Some((victim, at_us)) = crash {
            if victim == node.id.0 {
                if !node.crashed.load(Ordering::SeqCst)
                    && sh.start.elapsed().as_secs_f64() * 1e6 >= at_us
                {
                    crash_self(&sh, &node, &mailbox);
                }
                if node.crashed.load(Ordering::SeqCst) {
                    // Zombie mode: silently bury anything that slipped
                    // past the fabric gate (a delivery racing
                    // `arm_crash`) until the leader flips our shutdown
                    // flag directly — a dead node cannot receive the
                    // broadcast.
                    if let Some(env) = mailbox.recv_timeout(Duration::from_micros(200)) {
                        sh.net.bury(env);
                    }
                    continue;
                }
            }
            // Mirror leader-declared membership changes into the local
            // Safra ring and victim quarantine.
            let epoch = sh.recovery.epoch.load(Ordering::SeqCst);
            if epoch != seen_epoch {
                seen_epoch = epoch;
                sync_membership(&sh, &node);
            }
            // Idle heartbeat to the leader's failure detector, so a
            // quiet-but-live node is never suspected.
            if node.id.idx() != 0 && last_ping.elapsed() >= Duration::from_millis(1) {
                last_ping = Instant::now();
                sh.net.send(node.id, NodeId(0), Msg::Ping);
            }
            if node.id.idx() == 0 && last_scan.elapsed() >= Duration::from_micros(500) {
                last_scan = Instant::now();
                for p in 1..n {
                    if !sh.recovery.alive[p].load(Ordering::SeqCst) {
                        continue;
                    }
                    let silent_us = last_heard[p].elapsed().as_secs_f64() * 1e6;
                    if silent_us < suspicion_us {
                        continue;
                    }
                    if !suspected[p] {
                        suspected[p] = true;
                        sh.recovery.nodes_suspected.fetch_add(1, Ordering::SeqCst);
                    }
                    // Confirm against the fabric's gate before
                    // declaring: the suspicion threshold makes false
                    // positives implausible, the confirmation makes
                    // killing a slow live node impossible.
                    if sh.net.is_crashed(NodeId(p as u32)) {
                        leader_confirm_crash(&sh, &node, p, at_us);
                    }
                }
            }
        }
        let env = mailbox.recv_timeout(Duration::from_micros(200));
        if let Some(env) = env {
            if crash.is_some() {
                last_heard[env.src.idx()] = Instant::now();
            }
            // FaultMark contract (see `crate::faults`): a Dropped
            // envelope is delivered for Safra accounting only — count
            // the receive, discard the payload. A Duplicate is the
            // fabric's extra copy — process it (the protocol's request
            // ids dedup it) but do NOT count it, so the message deficit
            // stays balanced at one receive per send.
            if env.msg.is_basic() && env.fault != FaultMark::Duplicate {
                node.safra.lock().unwrap().on_receive(env.src);
            }
            if env.fault == FaultMark::Dropped {
                continue;
            }
            // A steal reply's sender IS the victim it reports on.
            let src = env.src;
            match env.msg {
                Msg::Activate { task } => activate_local(&node, graph, task),
                Msg::ActivateBatch { tasks } => activate_local_batch(&node, graph, &tasks),
                Msg::StealRequest { thief, req } => {
                    let faults_on = sh.cfg.faults.enabled;
                    if faults_on && !node.served_reqs.lock().unwrap().insert(req) {
                        // Fabric-duplicated request: the first copy was
                        // served. If its grant still awaits the ack,
                        // retransmit the stored reply verbatim (the
                        // thief dedups on `req`); otherwise the
                        // original answer already covers this copy.
                        let resend = node
                            .ledger
                            .lock()
                            .unwrap()
                            .get(&req)
                            .map(|e| e.reply.clone());
                        if let Some(msg) = resend {
                            node.safra.lock().unwrap().on_send(thief);
                            sh.net.send(node.id, thief, msg);
                        }
                        continue;
                    }
                    let workers = sh.cfg.workers_per_node;
                    // The gate's execution-time estimates (shared policy
                    // helpers, so the DES cannot diverge): EWMA or
                    // running mean node-wide (digest-seeded while this
                    // node is cold under --share-estimates), plus the
                    // per-class table under --exec-per-class — all O(1)
                    // reads of incrementally-maintained state.
                    let done = node.tasks_done.load(Ordering::SeqCst);
                    let ewma = f64::from_bits(node.exec_ewma_us_bits.load(Ordering::Relaxed));
                    let est = ExecSnapshot {
                        avg_us: exec_estimate_seeded_us(
                            sh.cfg.migrate.exec_ewma,
                            ewma,
                            node.exec_sum_ns.load(Ordering::SeqCst) as f64 / 1e3,
                            done,
                            f64::from_bits(node.remote_avg_us_bits.load(Ordering::Relaxed)),
                        ),
                        per_class: sh.cfg.migrate.exec_per_class.then(|| {
                            std::array::from_fn(|c| {
                                f64::from_bits(node.class_est_us_bits[c].load(Ordering::Relaxed))
                            })
                        }),
                    };
                    // The waiting-time gate prices the migration against
                    // the actual victim→thief link, not the cluster-wide
                    // base: a same-socket steal must not be denied at
                    // cross-rack cost.
                    let pair = sh
                        .cfg
                        .topology
                        .link_between(node.id.idx(), thief.idx(), sh.cfg.link);
                    let decision = decide_steal(
                        &sh.cfg.migrate,
                        graph,
                        node.queue.as_ref(),
                        workers,
                        &est,
                        pair.latency_us,
                        pair.bw_bytes_per_us,
                    );
                    for t in &decision.tasks {
                        class_dec(&node, t.class);
                    }
                    {
                        let mut st = node.steal.lock().unwrap();
                        st.requests_served += 1;
                        if decision.tasks.is_empty() {
                            if decision.denied_by_waiting_time {
                                st.waiting_time_denials += 1;
                            } else {
                                st.empty_denials += 1;
                            }
                        } else {
                            st.tasks_migrated += decision.tasks.len() as u64;
                            st.payload_bytes += decision.payload_bytes;
                        }
                    }
                    // Execution-time knowledge travels with stolen work
                    // (--share-estimates): a granted reply carries this
                    // victim's estimate digest, priced into wire_bytes.
                    let digest = (sh.cfg.migrate.share_estimates && !decision.tasks.is_empty())
                        .then(|| steal_digest(&node, est.avg_us, done));
                    let granted = decision.tasks.clone();
                    let reply = Msg::StealReply {
                        req,
                        tasks: decision.tasks,
                        payload_bytes: decision.payload_bytes,
                        digest,
                        denied_by_waiting_time: decision.denied_by_waiting_time,
                    };
                    if faults_on && !granted.is_empty() {
                        // Park the granted tasks in the transfer ledger
                        // until the thief acks: order matters — the
                        // tasks must be accounted somewhere before the
                        // reply leaves, or a dropped reply could race a
                        // Safra probe into a false termination.
                        node.ledger_tasks.fetch_add(granted.len(), Ordering::SeqCst);
                        node.ledger.lock().unwrap().insert(
                            req,
                            LedgerEntry {
                                thief,
                                tasks: granted,
                                reply: reply.clone(),
                                sent_at: Instant::now(),
                                attempt: 0,
                            },
                        );
                    }
                    node.safra.lock().unwrap().on_send(thief);
                    sh.net.send(node.id, thief, reply);
                }
                Msg::StealReply {
                    req,
                    tasks,
                    payload_bytes,
                    digest,
                    denied_by_waiting_time,
                } => {
                    let faults_on = sh.cfg.faults.enabled;
                    // Resolve the reply atomically against the timeout
                    // scan (one StealBook lock): either this request is
                    // already resolved — duplicate/late reply, suppress
                    // and re-answer with the ack the victim's
                    // retransmit loop is waiting for — or this reply
                    // resolves it now.
                    let granted = !tasks.is_empty();
                    let mut refused = false;
                    let dup = {
                        let mut book = node.steal_book.lock().unwrap();
                        match book.resolved.get(&req).copied() {
                            Some(res) => Some(res),
                            None => {
                                // A grant from a victim already
                                // declared dead is refused: the
                                // recovery sweep owns (or re-homed)
                                // the parked tasks, so accepting here
                                // would double-execute them. Decided
                                // inside this critical section — the
                                // sweep's probe of this book and the
                                // SeqCst membership flip before it
                                // make every interleaving exactly-once.
                                refused = faults_on
                                    && granted
                                    && crash.is_some()
                                    && !sh.recovery.alive[src.idx()].load(Ordering::SeqCst);
                                // Release the inflight slot only on a
                                // matched request: an unmatched reply
                                // must not push the counter negative —
                                // the pre-PR 7 accounting decremented
                                // unconditionally and leaked on every
                                // abandoned path.
                                if book.pending.remove(&req).is_some() {
                                    node.inflight_steals.fetch_sub(1, Ordering::SeqCst);
                                }
                                if faults_on {
                                    book.resolved.insert(
                                        req,
                                        if refused {
                                            StealResolution::Abandoned
                                        } else if granted {
                                            StealResolution::AckedGrant
                                        } else {
                                            StealResolution::AckedDenial
                                        },
                                    );
                                }
                                None
                            }
                        }
                    };
                    if let Some(res) = dup {
                        node.dup_replies_suppressed.fetch_add(1, Ordering::Relaxed);
                        let ack = match res {
                            StealResolution::AckedGrant => Some(true),
                            StealResolution::Abandoned => Some(false),
                            StealResolution::AckedDenial => None,
                        };
                        if let Some(accepted) = ack {
                            node.safra.lock().unwrap().on_send(src);
                            sh.net
                                .send(node.id, src, Msg::TransferAck { req, accepted });
                        }
                        continue;
                    }
                    let hierarchical = sh.cfg.steal_domains == StealDomains::Hierarchical;
                    if refused {
                        // Telemetry mirrors a timeout (no ack — the
                        // dead victim's ledger is swept, not retired;
                        // no digest merge; no grant recorded) and the
                        // victim is quarantined for good measure.
                        node.steal_timeouts.fetch_add(1, Ordering::Relaxed);
                        node.victim_timeouts[src.idx()].fetch_add(1, Ordering::Relaxed);
                        quarantine_victim(&node, src.idx());
                        if hierarchical {
                            node.escalation.lock().unwrap().on_miss();
                        }
                        continue;
                    }
                    if faults_on && granted {
                        // Ack the transfer so the victim retires its
                        // ledger entry; denials keep none.
                        node.safra.lock().unwrap().on_send(src);
                        sh.net
                            .send(node.id, src, Msg::TransferAck { req, accepted: true });
                    }
                    // Per-victim outcome telemetry (always) and the
                    // targeted selector's history (only when it will be
                    // consulted — uniform mode never takes the lock).
                    let outcome = classify_reply(!tasks.is_empty(), denied_by_waiting_time);
                    let table = match outcome {
                        VictimOutcome::Granted => &node.victim_grants,
                        VictimOutcome::DeniedWaitingTime => &node.victim_wt_denials,
                        VictimOutcome::DeniedEmpty => &node.victim_empties,
                        VictimOutcome::TimedOut => &node.victim_timeouts,
                        // classify_reply never yields Quarantined — it
                        // is a membership verdict, not a reply outcome.
                        VictimOutcome::Quarantined => &node.victim_quarantined,
                    };
                    table[src.idx()].fetch_add(1, Ordering::Relaxed);
                    if sh.cfg.migrate.victim_select == VictimSelect::Targeted {
                        node.victim_sel
                            .lock()
                            .unwrap()
                            .record(src.idx(), outcome, digest.as_ref());
                    }
                    // A grant narrows the escalation back to the home
                    // tier; any denial is a miss that (after the
                    // per-tier budget) widens the next search outward.
                    if hierarchical {
                        let mut esc = node.escalation.lock().unwrap();
                        if tasks.is_empty() {
                            esc.on_miss();
                        } else {
                            esc.on_grant();
                        }
                    }
                    // Merge the victim's estimates BEFORE the stolen
                    // tasks enter the queue: the very next gate decision
                    // on this node must already see the seeded table.
                    if let Some(d) = &digest {
                        merge_digest(&node, d);
                    }
                    if !tasks.is_empty() {
                        {
                            let mut st = node.steal.lock().unwrap();
                            st.successful_steals += 1;
                            st.tasks_received += tasks.len() as u64;
                        }
                        // Thief-side per-tier traffic: the grant and its
                        // wire bytes are booked to the victim's tier,
                        // same convention as `requests_sent`.
                        let tier = sh.cfg.topology.tier_of(node.id.idx(), src.idx());
                        node.tier_steal_grants[tier].fetch_add(1, Ordering::Relaxed);
                        node.tier_steal_bytes[tier].fetch_add(
                            Msg::steal_reply_wire_bytes(
                                tasks.len(),
                                payload_bytes,
                                digest.as_ref(),
                            ),
                            Ordering::Relaxed,
                        );
                        if sh.cfg.record_polls {
                            // Fig. 3 instrumentation: queue length each
                            // stolen task would have seen arriving
                            // one-by-one (len, len+1, …), sampled before
                            // the batch insert.
                            let ready = node.queue.len() as u32;
                            let t_us = sh.start.elapsed().as_nanos() as f64 / 1e3;
                            let mut ar = node.arrival_ready.lock().unwrap();
                            for k in 0..tasks.len() as u32 {
                                ar.push(PollSample {
                                    t_us,
                                    ready: ready + k,
                                });
                            }
                        }
                        // Recreate the stolen tasks locally (same uids)
                        // in one batched insert: one queue-lock
                        // acquisition per reply, not one per task.
                        enqueue_batch(&node, graph, &tasks, BatchSite::StealReply);
                    }
                }
                Msg::TransferAck { req, accepted } => {
                    // Retire (ack) or reclaim (nack) the ledger entry.
                    // Unknown req = the entry was already retired by an
                    // earlier copy of this ack — idempotent no-op.
                    let entry = node.ledger.lock().unwrap().remove(&req);
                    if let Some(entry) = entry {
                        if !accepted {
                            // The thief abandoned the transfer: the
                            // tasks come home through the same batch
                            // site a gate denial uses. Reinsert before
                            // releasing the ledger accounting so the
                            // node never looks passive in between.
                            node.ledger_reclaims.fetch_add(1, Ordering::Relaxed);
                            enqueue_batch(&node, graph, &entry.tasks, BatchSite::GateDenial);
                        }
                        node.ledger_tasks
                            .fetch_sub(entry.tasks.len(), Ordering::SeqCst);
                    }
                }
                Msg::Recover { tasks } => {
                    // Re-homed ready work from a dead node: its
                    // dependencies were satisfied there, so it bypasses
                    // the activation tracker (the message is basic —
                    // already counted above — so Safra stays exact).
                    enqueue_batch(&node, graph, &tasks, BatchSite::Other);
                }
                Msg::Ping => {
                    // Heartbeat: `last_heard` above is the payload.
                }
                Msg::Token(tok) => {
                    let passive = node.passive();
                    let action = node.safra.lock().unwrap().on_token(tok, passive);
                    perform_safra_action(&sh, &node, action);
                }
                Msg::Shutdown => {
                    node.shutdown.store(true, Ordering::SeqCst);
                    node.queue_cv.notify_all();
                    return;
                }
            }
        }

        // Parked token: retry forwarding whenever we might be passive.
        let passive = node.passive();
        if passive {
            let action = node.safra.lock().unwrap().try_forward(true);
            perform_safra_action(&sh, &node, action);
        }

        // Leader initiates probes while passive (rate-limited).
        if node.id.idx() == 0 && passive && last_probe.elapsed() > Duration::from_micros(500) {
            last_probe = Instant::now();
            let action = node.safra.lock().unwrap().leader_start_probe(true);
            perform_safra_action(&sh, &node, action);
        }
    }
}

fn perform_safra_action(sh: &Arc<Shared>, node: &Arc<NodeState>, action: SafraAction) {
    match action {
        SafraAction::None => {}
        SafraAction::Forward(dst, tok) => {
            sh.net.send(node.id, dst, Msg::Token(tok));
        }
        SafraAction::Terminate => {
            if let Some((dead, _)) = sh.recovery.crash {
                let dead_id = NodeId(dead);
                if sh.net.is_crashed(dead_id)
                    && (!sh.net.graveyard_is_empty() || sh.net.inflight_to(dead_id))
                {
                    // Buried basic sends were spliced out of the Safra
                    // deficit by the ring repair, so the detector is
                    // blind to them: a white token is not proof while
                    // traffic to the dead node is buried or still in
                    // flight. Re-inject (counted sends re-blacken the
                    // ring) and swallow the termination — the leader
                    // re-probes on its cadence.
                    reinject_graveyard(sh, node);
                    return;
                }
            }
            // Leader announces shutdown to everyone, then stops itself.
            sh.net.broadcast_from(node.id, Msg::Shutdown);
            node.shutdown.store(true, Ordering::SeqCst);
            node.queue_cv.notify_all();
            // A crashed node cannot receive the broadcast (the fabric
            // buries it): flip its flag directly so its zombie comm
            // thread can join. Done even before the crash instant —
            // idempotent with the broadcast — so a crash racing the
            // shutdown can never strand the victim's threads.
            if let Some((dead, _)) = sh.recovery.crash {
                let dn = &sh.nodes[dead as usize];
                dn.shutdown.store(true, Ordering::SeqCst);
                let _idle = dn.idle.lock().unwrap();
                dn.queue_cv.notify_all();
            }
        }
    }
}

fn migrate_loop(sh: Arc<Shared>, node: Arc<NodeState>) {
    let mut rng = thief_rng(sh.cfg.seed, node.id.idx());
    let n = sh.nodes.len();
    let poll = Duration::from_nanos((sh.cfg.migrate.poll_interval_us * 1e3) as u64);
    loop {
        if node.shutdown.load(Ordering::SeqCst) || node.crashed.load(Ordering::SeqCst) {
            return;
        }
        std::thread::sleep(poll);
        if sh.cfg.faults.enabled {
            scan_steal_timeouts(&sh, &node);
            scan_ledger_acks(&sh, &node);
        }
        // Both fields are O(1) counter reads — the starvation poll no
        // longer walks the executing set calling successors() per task.
        let view = StarvationView {
            ready: node.queue.len(),
            executing_local_successors: match sh.cfg.migrate.thief {
                crate::migrate::ThiefPolicy::ReadyOnly => 0,
                crate::migrate::ThiefPolicy::ReadySuccessors => {
                    node.executing_local_succ.load(Ordering::SeqCst)
                }
            },
        };
        if is_starving(sh.cfg.migrate.thief, view)
            && node.inflight_steals.load(Ordering::SeqCst) < sh.cfg.migrate.max_inflight
        {
            let me = node.id.idx();
            let hierarchical = sh.cfg.steal_domains == StealDomains::Hierarchical;
            let victim = match sh.cfg.migrate.victim_select {
                VictimSelect::Uniform if hierarchical => {
                    // Hierarchical uniform: draw among the live peers
                    // of the current escalation tier, widening only
                    // when the tier's misses exhaust its budget. Empty
                    // tier (everyone near is dead) → all live peers.
                    let tier = node.escalation.lock().unwrap().tier();
                    let near: Vec<usize> = sh
                        .cfg
                        .topology
                        .peers_within(me, n, tier)
                        .into_iter()
                        .filter(|&p| sh.recovery.alive[p].load(Ordering::SeqCst))
                        .collect();
                    let cands = if near.is_empty() {
                        let live: Vec<usize> = (0..n)
                            .filter(|&p| {
                                p != me && sh.recovery.alive[p].load(Ordering::SeqCst)
                            })
                            .collect();
                        if live.is_empty() {
                            continue;
                        }
                        live
                    } else {
                        near
                    };
                    NodeId(cands[rng.below(cands.len() as u64) as usize] as u32)
                }
                VictimSelect::Uniform => {
                    // Membership-aware uniform draw, DES-mirrored:
                    // while everyone is alive this is the exact
                    // historical `pick_other` (byte-identical draw
                    // sequence); after a crash it is the k-th-live
                    // equivalent over the survivors.
                    if sh.recovery.epoch.load(Ordering::SeqCst) == 0 {
                        NodeId(rng.pick_other(n, me) as u32)
                    } else {
                        let live: Vec<usize> = (0..n)
                            .filter(|&p| {
                                p != me && sh.recovery.alive[p].load(Ordering::SeqCst)
                            })
                            .collect();
                        if live.is_empty() {
                            continue;
                        }
                        NodeId(live[rng.below(live.len() as u64) as usize] as u32)
                    }
                }
                VictimSelect::Targeted => {
                    // The selector's fallback win per stolen task is the
                    // thief's own node-wide estimate — the same quantity
                    // the victim-side gate runs on, digest-seeded while
                    // this node is still cold under --share-estimates.
                    let done = node.tasks_done.load(Ordering::SeqCst);
                    let ewma = f64::from_bits(node.exec_ewma_us_bits.load(Ordering::Relaxed));
                    let fallback = exec_estimate_seeded_us(
                        sh.cfg.migrate.exec_ewma,
                        ewma,
                        node.exec_sum_ns.load(Ordering::SeqCst) as f64 / 1e3,
                        done,
                        f64::from_bits(node.remote_avg_us_bits.load(Ordering::Relaxed)),
                    );
                    // Class-aware scoring sees this thief's queue mix;
                    // hierarchical mode scopes the candidate walk to
                    // the current escalation tier.
                    let mix = sh.cfg.migrate.track_per_class().then(|| {
                        std::array::from_fn(|c| {
                            node.queued_class[c].load(Ordering::Relaxed) as usize
                        })
                    });
                    let domain = hierarchical.then(|| {
                        let tier = node.escalation.lock().unwrap().tier();
                        let mask: Vec<bool> = (0..n)
                            .map(|p| sh.cfg.topology.in_domain(me, p, tier))
                            .collect();
                        mask
                    });
                    NodeId(node.victim_sel.lock().unwrap().pick_scoped(
                        fallback,
                        domain.as_deref(),
                        mix.as_ref(),
                    ) as u32)
                }
            };
            node.inflight_steals.fetch_add(1, Ordering::SeqCst);
            node.steal.lock().unwrap().requests_sent += 1;
            let tier = sh.cfg.topology.tier_of(me, victim.idx());
            node.tier_steal_requests[tier].fetch_add(1, Ordering::Relaxed);
            let req = steal_req_id(node.id.0, node.next_req.fetch_add(1, Ordering::Relaxed));
            node.steal_book.lock().unwrap().pending.insert(
                req,
                PendingSteal {
                    victim,
                    sent_at: Instant::now(),
                    attempt: 0,
                },
            );
            node.safra.lock().unwrap().on_send(victim);
            sh.net
                .send(node.id, victim, Msg::StealRequest { thief: node.id, req });
        }
    }
}

/// Thief-side timeout sweep (`--faults` only, from the migrate
/// thread): every pending request older than its
/// [`steal_timeout_us`] deadline is abandoned — nacked so the victim
/// reclaims any parked grant — and, while the retry budget lasts,
/// re-issued to the same victim under a fresh request id with the
/// inflight slot retained. Budget exhausted → the slot is released.
fn scan_steal_timeouts(sh: &Arc<Shared>, node: &Arc<NodeState>) {
    let now = Instant::now();
    let mc = &sh.cfg.migrate;
    let expired: Vec<(u64, PendingSteal)> = node
        .steal_book
        .lock()
        .unwrap()
        .pending
        .iter()
        .filter(|(_, p)| {
            // Deadline from the actual thief→victim link: a same-socket
            // request must not wait out a cross-rack round trip.
            let pair = sh
                .cfg
                .topology
                .link_between(node.id.idx(), p.victim.idx(), sh.cfg.link);
            now.duration_since(p.sent_at).as_secs_f64() * 1e6
                >= steal_timeout_us(
                    pair.latency_us,
                    pair.bw_bytes_per_us,
                    mc.migrate_overhead_us,
                    mc.poll_interval_us,
                    p.attempt,
                )
        })
        .map(|(r, p)| (*r, *p))
        .collect();
    for (req, p) in expired {
        // Claim the request atomically against the comm thread's
        // resolve (one StealBook lock): remove it from pending and
        // mark it Abandoned in one critical section, so a racing reply
        // is suppressed (and re-nacked) instead of double-resolving.
        // If the remove misses, the reply won — this timeout never
        // happened.
        let claimed = {
            let mut book = node.steal_book.lock().unwrap();
            if book.pending.remove(&req).is_some() {
                book.resolved.insert(req, StealResolution::Abandoned);
                true
            } else {
                false
            }
        };
        if !claimed {
            continue;
        }
        node.steal_timeouts.fetch_add(1, Ordering::Relaxed);
        node.victim_timeouts[p.victim.idx()].fetch_add(1, Ordering::Relaxed);
        // A timeout is a denial-flavored signal to the scheduler: the
        // fabric just proved migration is slower than planned.
        node.queue.feedback(StealOutcome::TimedOut);
        if sh.cfg.steal_domains == StealDomains::Hierarchical {
            node.escalation.lock().unwrap().on_miss();
        }
        let victim_dead = sh.recovery.crash.is_some()
            && !sh.recovery.alive[p.victim.idx()].load(Ordering::SeqCst);
        if victim_dead {
            // Declared dead: no nack (the recovery sweep settles its
            // ledger, nobody retransmits) and no retry — quarantine
            // and release the inflight slot.
            quarantine_victim(node, p.victim.idx());
            node.inflight_steals.fetch_sub(1, Ordering::SeqCst);
            continue;
        }
        if mc.victim_select == VictimSelect::Targeted {
            node.victim_sel.lock().unwrap().record(
                p.victim.idx(),
                VictimOutcome::TimedOut,
                None,
            );
        }
        // Nack so a grant parked in the victim's ledger comes home.
        node.safra.lock().unwrap().on_send(p.victim);
        sh.net
            .send(node.id, p.victim, Msg::TransferAck { req, accepted: false });
        if p.attempt < THIEF_RETRY_BUDGET {
            let retry = steal_req_id(node.id.0, node.next_req.fetch_add(1, Ordering::Relaxed));
            node.steal_book.lock().unwrap().pending.insert(
                retry,
                PendingSteal {
                    victim: p.victim,
                    sent_at: Instant::now(),
                    attempt: p.attempt + 1,
                },
            );
            node.steal_retries.fetch_add(1, Ordering::Relaxed);
            node.steal.lock().unwrap().requests_sent += 1;
            let tier = sh.cfg.topology.tier_of(node.id.idx(), p.victim.idx());
            node.tier_steal_requests[tier].fetch_add(1, Ordering::Relaxed);
            node.safra.lock().unwrap().on_send(p.victim);
            sh.net.send(
                node.id,
                p.victim,
                Msg::StealRequest {
                    thief: node.id,
                    req: retry,
                },
            );
        } else {
            // The whole retry budget expired without one answered
            // request. A transient fabric (per-class fault probability
            // capped below 1) is overwhelmingly unlikely to eat every
            // attempt, so treat the victim as effectively failed —
            // crash-stopped or permanently stalled — and quarantine it
            // instead of feeding it requests forever (the PR 7
            // liveness caveat, closed).
            quarantine_victim(node, p.victim.idx());
            node.inflight_steals.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

/// Victim-side ack sweep (`--faults` only, from the migrate thread):
/// ledger entries whose ack is overdue get their stored reply
/// retransmitted verbatim, with the same capped backoff as the thief's
/// timeout. Retransmits are bounded by [`ACK_PROBE_BUDGET`]: once the
/// budget is spent — or the thief is declared dead by membership — the
/// victim settles the entry directly from the thief's resolution book
/// instead of retransmitting forever into a black hole (the PR 7
/// liveness caveat). The probe is atomic against the thief's own
/// resolve (same lock): an accepted grant retires the entry, anything
/// else is marked Abandoned at the thief (suppressing any
/// still-in-flight reply) and reclaimed here — exactly once either
/// way.
fn scan_ledger_acks(sh: &Arc<Shared>, node: &Arc<NodeState>) {
    let graph = sh.graph.as_ref();
    let now = Instant::now();
    let mc = &sh.cfg.migrate;
    let mut resend: Vec<(NodeId, Msg)> = Vec::new();
    let mut probes: Vec<(u64, NodeId)> = Vec::new();
    {
        let mut ledger = node.ledger.lock().unwrap();
        for (&req, e) in ledger.iter_mut() {
            let pair = sh
                .cfg
                .topology
                .link_between(node.id.idx(), e.thief.idx(), sh.cfg.link);
            let deadline = steal_timeout_us(
                pair.latency_us,
                pair.bw_bytes_per_us,
                mc.migrate_overhead_us,
                mc.poll_interval_us,
                e.attempt,
            );
            if now.duration_since(e.sent_at).as_secs_f64() * 1e6 < deadline {
                continue;
            }
            let thief_dead = sh.recovery.crash.is_some()
                && !sh.recovery.alive[e.thief.idx()].load(Ordering::SeqCst);
            if thief_dead || e.attempt >= ACK_PROBE_BUDGET {
                probes.push((req, e.thief));
            } else {
                e.sent_at = now;
                e.attempt += 1;
                resend.push((e.thief, e.reply.clone()));
            }
        }
    }
    for (thief, reply) in resend {
        node.safra.lock().unwrap().on_send(thief);
        sh.net.send(node.id, thief, reply);
    }
    probes.sort_unstable_by_key(|(req, _)| *req);
    for (req, thief_id) in probes {
        let thief = &sh.nodes[thief_id.idx()];
        let settled = {
            let mut book = thief.steal_book.lock().unwrap();
            match book.resolved.get(&req).copied() {
                Some(r) => r,
                None => {
                    // Unresolved at the thief: abandon it there, in
                    // the same critical section, so a reply that is
                    // still crawling through the fabric is suppressed
                    // instead of enqueued after our reclaim.
                    if book.pending.remove(&req).is_some() {
                        thief.inflight_steals.fetch_sub(1, Ordering::SeqCst);
                    }
                    book.resolved.insert(req, StealResolution::Abandoned);
                    StealResolution::Abandoned
                }
            }
        };
        // The entry may have been retired by an ack racing the probe —
        // then there is nothing left to settle.
        let entry = node.ledger.lock().unwrap().remove(&req);
        if let Some(entry) = entry {
            if settled != StealResolution::AckedGrant {
                node.ledger_reclaims.fetch_add(1, Ordering::Relaxed);
                enqueue_batch(node, graph, &entry.tasks, BatchSite::GateDenial);
            }
            node.ledger_tasks
                .fetch_sub(entry.tasks.len(), Ordering::SeqCst);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node::executor::{NullExecutor, SpinExecutor};
    use crate::sim::CostModel;
    use crate::workloads::{CholeskyGraph, CholeskyParams, UtsGraph, UtsParams};

    fn chol(tiles: u32, nodes: u32) -> Arc<CholeskyGraph> {
        Arc::new(CholeskyGraph::new(CholeskyParams {
            tiles,
            tile_size: 8,
            nodes,
            dense_fraction: 0.5,
            seed: 3,
            all_dense: false,
        }))
    }

    #[test]
    fn null_executor_cholesky_no_steal() {
        let g = chol(8, 2);
        let total = g.total_tasks().unwrap();
        let r = Cluster::run(
            g,
            ClusterConfig::default()
                .with_workers_per_node(2)
                .with_migrate(MigrateConfig::disabled()),
            Arc::new(NullExecutor),
        );
        assert_eq!(r.tasks_total_executed(), total);
    }

    #[test]
    fn null_executor_cholesky_with_steal() {
        let g = chol(8, 3);
        let total = g.total_tasks().unwrap();
        let r = Cluster::run(
            g,
            ClusterConfig::default()
                .with_workers_per_node(2)
                .with_migrate(MigrateConfig::default().with_poll_interval_us(50.0)),
            Arc::new(NullExecutor),
        );
        assert_eq!(r.tasks_total_executed(), total);
        // Faults off: none of the self-healing machinery may engage.
        for n in &r.nodes {
            assert_eq!(n.steal_timeouts, 0);
            assert_eq!(n.steal_retries, 0);
            assert_eq!(n.ledger_reclaims, 0);
            assert_eq!(n.dup_replies_suppressed, 0);
            assert!(n.victim_timeouts.iter().all(|&t| t == 0));
        }
    }

    /// The acceptance scenario: an 8-node Cholesky over a fabric that
    /// drops 20% of steal replies (and duplicates 10% of everything)
    /// still executes every task exactly once — dropped grants come
    /// home through the transfer ledger's nack-reclaim, duplicated
    /// replies are suppressed by request id, and the end-of-run
    /// asserts inside [`Cluster::run`] prove zero ledger residue and
    /// zero inflight-slot leaks.
    #[test]
    fn faulty_fabric_cholesky_completes_exactly_once() {
        let g = chol(10, 8);
        let total = g.total_tasks().unwrap();
        let r = Cluster::run(
            g,
            ClusterConfig::default()
                .with_workers_per_node(2)
                .with_migrate(MigrateConfig::default().with_poll_interval_us(50.0))
                .with_faults("drop-reply=0.2,dup=0.1".parse().unwrap()),
            Arc::new(NullExecutor),
        );
        assert_eq!(
            r.tasks_total_executed(),
            total,
            "exactly-once under 20% reply loss"
        );
    }

    /// Same under an irregular workload with real (spinning) task
    /// bodies and a plan that drops *and* delays every steal-message
    /// class — the worst case for the timeout derivation, since
    /// delayed replies race the retry path.
    #[test]
    fn faulty_fabric_uts_completes_exactly_once() {
        let g = Arc::new(UtsGraph::new(UtsParams {
            b0: 24,
            m: 4,
            q: 0.3,
            g: 30_000.0,
            seed: 5,
            nodes: 3,
            max_depth: 18,
        }));
        let size = g.tree_size(10_000_000);
        let r = Cluster::run(
            g,
            ClusterConfig::default()
                .with_workers_per_node(2)
                .with_migrate(MigrateConfig::default().with_poll_interval_us(30.0))
                .with_faults("drop=0.2,delay=2x,delay-p=0.3".parse().unwrap()),
            Arc::new(SpinExecutor::new(CostModel::default_calibrated(), 0, |_| {
                30_000.0
            })),
        );
        assert_eq!(r.tasks_total_executed(), size);
    }

    #[test]
    fn spin_executor_uts_spreads_work() {
        let g = Arc::new(UtsGraph::new(UtsParams {
            b0: 24,
            m: 4,
            q: 0.3,
            g: 30_000.0, // 30 µs/task
            seed: 5,
            nodes: 3,
            max_depth: 18,
        }));
        let size = g.tree_size(10_000_000);
        let r = Cluster::run(
            g,
            ClusterConfig::default()
                .with_workers_per_node(2)
                .with_migrate(MigrateConfig::default().with_poll_interval_us(30.0)),
            Arc::new(SpinExecutor::new(CostModel::default_calibrated(), 0, |_| {
                30_000.0
            })),
        );
        assert_eq!(r.tasks_total_executed(), size);
        let spread: u64 = r.nodes[1..].iter().map(|n| n.tasks_executed).sum();
        assert!(spread > 0, "steals moved work off node 0");
        assert!(r.total_steals().successful_steals > 0);
    }

    #[test]
    fn single_node_terminates() {
        let g = chol(5, 1);
        let r = Cluster::run(
            g,
            ClusterConfig::default().with_workers_per_node(2),
            Arc::new(NullExecutor),
        );
        assert_eq!(r.tasks_total_executed(), 35);
    }

    /// The unbatched (per-edge) activation path stays available as an
    /// ablation and must complete every task, stealing or not.
    #[test]
    fn unbatched_activation_path_still_completes() {
        for steal in [false, true] {
            let g = chol(8, 3);
            let total = g.total_tasks().unwrap();
            let r = Cluster::run(
                g,
                ClusterConfig::default()
                    .with_workers_per_node(2)
                    .with_batch_activations(false)
                    .with_migrate(if steal {
                        MigrateConfig::default().with_poll_interval_us(50.0)
                    } else {
                        MigrateConfig::disabled()
                    }),
                Arc::new(NullExecutor),
            );
            assert_eq!(r.tasks_total_executed(), total, "steal={steal}");
        }
    }

    /// The closed loop end to end in the threaded runtime: an
    /// all-on-node-0 UTS run whose migrate overhead makes every steal
    /// lose the waiting-time comparison must deny heavily and raise
    /// node 0's sharded spill watermark through the feedback hook
    /// (central runs the same scenario and records the denials).
    #[test]
    fn denial_heavy_run_raises_sharded_watermark() {
        use crate::sched::SPILL_THRESHOLD;
        for sched in SchedBackend::ALL {
            let g = Arc::new(UtsGraph::new(UtsParams {
                b0: 24,
                m: 4,
                q: 0.3,
                g: 30_000.0, // 30 µs/task
                seed: 5,
                nodes: 3,
                max_depth: 18,
            }));
            let size = g.tree_size(10_000_000);
            let r = Cluster::run(
                g,
                ClusterConfig::default()
                    .with_workers_per_node(2)
                    .with_sched(sched)
                    .with_migrate(
                        MigrateConfig::default()
                            .with_poll_interval_us(30.0)
                            // gate always denies
                            .with_migrate_overhead_us(1e9),
                    ),
                Arc::new(SpinExecutor::new(CostModel::default_calibrated(), 0, |_| {
                    30_000.0
                })),
            );
            assert_eq!(r.tasks_total_executed(), size, "{sched:?}");
            let steals = r.total_steals();
            assert_eq!(steals.successful_steals, 0, "{sched:?}: gate denies all");
            assert!(
                steals.waiting_time_denials > 0,
                "{sched:?}: wanted denials, got {steals:?}"
            );
            let fed: u64 = r.nodes.iter().map(|n| n.sched.feedback_wt_denials).sum();
            assert!(fed > 0, "{sched:?}: denials fed back");
            if sched == SchedBackend::Sharded {
                assert!(
                    r.nodes[0].sched.watermark > SPILL_THRESHOLD as u64,
                    "denials must raise the watermark, got {}",
                    r.nodes[0].sched.watermark
                );
                // The overhead floor proves every denial from the O(1)
                // accounting, so extraction never runs — and therefore
                // never pays the all-shards fallback walk.
                let walks: u64 = r.nodes.iter().map(|n| n.sched.extract_fallback_walks).sum();
                assert_eq!(walks, 0, "certain denials must skip extraction");
            }
        }
    }

    /// Thief-side steal-reply re-enqueue is one batched insert per
    /// non-empty reply (gate off, so nothing else batches).
    #[test]
    fn steal_reply_reenqueue_batches_once_per_reply() {
        let g = Arc::new(UtsGraph::new(UtsParams {
            b0: 24,
            m: 4,
            q: 0.3,
            g: 30_000.0,
            seed: 5,
            nodes: 3,
            max_depth: 18,
        }));
        let size = g.tree_size(10_000_000);
        let r = Cluster::run(
            g,
            ClusterConfig::default().with_workers_per_node(2).with_migrate(
                MigrateConfig::default()
                    .with_poll_interval_us(30.0)
                    .with_use_waiting_time(false)
                    .with_victim(crate::migrate::VictimPolicy::Chunk(4)),
            ),
            Arc::new(SpinExecutor::new(CostModel::default_calibrated(), 0, |_| {
                30_000.0
            })),
        );
        assert_eq!(r.tasks_total_executed(), size);
        let steals = r.total_steals();
        assert!(steals.successful_steals > 0);
        // Per-call-site accounting keeps the reply assertion exact even
        // though activation ready sets batch on the same queues.
        let reply: Vec<_> = r
            .nodes
            .iter()
            .map(|n| n.sched.site(BatchSite::StealReply))
            .collect();
        let batches: u64 = reply.iter().map(|b| b.batches).sum();
        let saved: u64 = reply.iter().map(|b| b.saved_locks()).sum();
        assert_eq!(
            batches, steals.successful_steals,
            "exactly one batched insert per non-empty reply"
        );
        assert_eq!(saved, steals.tasks_received - steals.successful_steals);
    }

    /// The batch-first activation pipeline e2e: every non-empty ready
    /// set delivered through the batched path performs exactly one
    /// activation-site batched insert — the runtime-layer ready-set
    /// count and the scheduler-layer batch counter must agree per node
    /// — and the ablation flag restores the per-edge protocol.
    #[test]
    fn activation_ready_sets_batch_exactly_once() {
        let run = |batch: bool| {
            let g = Arc::new(CholeskyGraph::new(CholeskyParams {
                tiles: 10,
                tile_size: 8,
                nodes: 3,
                dense_fraction: 1.0,
                seed: 3,
                all_dense: true,
            }));
            let total = g.total_tasks().unwrap();
            let r = Cluster::run(
                g,
                ClusterConfig::default()
                    .with_workers_per_node(2)
                    .with_batch_activations(batch)
                    .with_migrate(MigrateConfig::disabled()),
                Arc::new(NullExecutor),
            );
            assert_eq!(r.tasks_total_executed(), total, "batch={batch}");
            r
        };
        let r = run(true);
        let mut ready_sets = 0;
        for (ix, n) in r.nodes.iter().enumerate() {
            assert_eq!(
                n.sched.site(BatchSite::Activation).batches,
                n.activation_ready_batches,
                "node {ix}: one batched insert per non-empty ready set"
            );
            ready_sets += n.activation_ready_batches;
        }
        assert!(ready_sets > 0, "dense Cholesky fan-out must batch");
        // Nothing else books the activation site.
        let unbatched = run(false);
        for n in &unbatched.nodes {
            assert_eq!(n.sched.site(BatchSite::Activation).batches, 0);
            assert_eq!(n.activation_ready_batches, 0);
        }
    }

    /// `--exec-per-class` in the threaded runtime: the gate runs on the
    /// per-class estimator table, every task still executes exactly
    /// once, and the finished classes have populated their estimates.
    #[test]
    fn exec_per_class_run_completes_and_populates_table() {
        let g = chol(8, 3);
        let total = g.total_tasks().unwrap();
        let g2 = g.clone();
        let ex = SpinExecutor::new(CostModel::default_calibrated(), 8, move |t| g2.work_units(t))
            .with_time_scale(0.05);
        let r = Cluster::run(
            g,
            ClusterConfig::default().with_workers_per_node(2).with_migrate(
                MigrateConfig::default()
                    .with_poll_interval_us(50.0)
                    .with_exec_per_class(true),
            ),
            Arc::new(ex),
        );
        assert_eq!(r.tasks_total_executed(), total);
        let gemm_est: f64 = r
            .nodes
            .iter()
            .map(|n| n.class_est_us[TaskClass::Gemm.idx()])
            .fold(0.0, f64::max);
        assert!(gemm_est > 0.0, "GEMM completions seeded the class table");
        let uts_est: f64 = r
            .nodes
            .iter()
            .map(|n| n.class_est_us[TaskClass::UtsNode.idx()])
            .fold(0.0, f64::max);
        assert_eq!(uts_est, 0.0, "no UTS tasks ran, so no UTS estimate");
    }

    /// `--share-estimates` in the threaded runtime: every granted steal
    /// reply carries the victim's digest, thieves merge it (cold classes
    /// adopted), and every task still executes exactly once.
    #[test]
    fn share_estimates_run_merges_digests() {
        let g = Arc::new(UtsGraph::new(UtsParams {
            b0: 24,
            m: 4,
            q: 0.3,
            g: 30_000.0,
            seed: 5,
            nodes: 3,
            max_depth: 18,
        }));
        let size = g.tree_size(10_000_000);
        let r = Cluster::run(
            g,
            ClusterConfig::default().with_workers_per_node(2).with_migrate(
                MigrateConfig::default()
                    .with_poll_interval_us(30.0)
                    .with_exec_per_class(true)
                    .with_share_estimates(true),
            ),
            Arc::new(SpinExecutor::new(CostModel::default_calibrated(), 0, |_| {
                30_000.0
            })),
        );
        assert_eq!(r.tasks_total_executed(), size);
        let steals = r.total_steals();
        assert!(steals.successful_steals > 0, "steals must land: {steals:?}");
        let merges: u64 = r.nodes.iter().map(|n| n.digest_merges).sum();
        assert_eq!(
            merges, steals.successful_steals,
            "every granted reply ships exactly one digest"
        );
        let adoptions: u64 = r.nodes.iter().map(|n| n.digest_class_adoptions).sum();
        assert!(
            adoptions > 0,
            "cold thieves must adopt the UTS class estimate"
        );
    }

    /// `--victim-select targeted` in the threaded runtime: every task
    /// still executes exactly once, steals land, and the per-victim
    /// outcome telemetry obeys its invariants — grants per node equal
    /// that node's successful steals (same code path), a node never
    /// records an outcome against itself, and at most `max_inflight`
    /// requests per node can be unanswered at shutdown.
    #[test]
    fn targeted_victim_selection_completes_and_accounts() {
        let g = Arc::new(UtsGraph::new(UtsParams {
            b0: 24,
            m: 4,
            q: 0.3,
            g: 30_000.0,
            seed: 5,
            nodes: 3,
            max_depth: 18,
        }));
        let size = g.tree_size(10_000_000);
        let r = Cluster::run(
            g,
            ClusterConfig::default().with_workers_per_node(2).with_migrate(
                MigrateConfig::default()
                    .with_poll_interval_us(30.0)
                    .with_share_estimates(true)
                    .with_victim_select(VictimSelect::Targeted),
            ),
            Arc::new(SpinExecutor::new(CostModel::default_calibrated(), 0, |_| {
                30_000.0
            })),
        );
        assert_eq!(r.tasks_total_executed(), size);
        let steals = r.total_steals();
        assert!(steals.successful_steals > 0, "steals must land: {steals:?}");
        for (ix, n) in r.nodes.iter().enumerate() {
            let grants: u64 = n.victim_grants.iter().sum();
            assert_eq!(
                grants, n.steal.successful_steals,
                "node {ix}: per-victim grants mirror successful steals"
            );
            assert_eq!(n.victim_grants[ix], 0, "node {ix}: never robs itself");
            assert_eq!(n.victim_wt_denials[ix] + n.victim_empties[ix], 0);
            let replies: u64 = grants
                + n.victim_wt_denials.iter().sum::<u64>()
                + n.victim_empties.iter().sum::<u64>();
            assert!(
                replies <= n.steal.requests_sent
                    && n.steal.requests_sent - replies <= 1,
                "node {ix}: ≤ max_inflight requests unanswered at shutdown \
                 ({replies} of {})",
                n.steal.requests_sent
            );
        }
    }

    /// `--exec-ewma` in the threaded runtime: the gate runs on the
    /// observed-execution EWMA and every task still runs exactly once.
    #[test]
    fn exec_ewma_run_completes() {
        let g = chol(8, 3);
        let total = g.total_tasks().unwrap();
        let r = Cluster::run(
            g,
            ClusterConfig::default().with_workers_per_node(2).with_migrate(
                MigrateConfig::default()
                    .with_poll_interval_us(50.0)
                    .with_exec_ewma(true),
            ),
            Arc::new(NullExecutor),
        );
        assert_eq!(r.tasks_total_executed(), total);
    }

    /// The sharded backend must run the full protocol — workers, comm,
    /// migrate thread, Safra termination — to the same task counts.
    #[test]
    fn sharded_backend_executes_every_task() {
        for steal in [false, true] {
            let g = chol(8, 3);
            let total = g.total_tasks().unwrap();
            let r = Cluster::run(
                g,
                ClusterConfig::default()
                    .with_workers_per_node(2)
                    .with_sched(SchedBackend::Sharded)
                    .with_migrate(if steal {
                        MigrateConfig::default().with_poll_interval_us(50.0)
                    } else {
                        MigrateConfig::disabled()
                    }),
                Arc::new(NullExecutor),
            );
            assert_eq!(r.tasks_total_executed(), total, "steal={steal}");
        }
    }

    /// The lock-free workassist backend must run the full protocol —
    /// workers, comm, migrate thread, Safra termination — to the same
    /// task counts, without ever taking a queue lock on any node.
    #[test]
    fn workassist_backend_executes_every_task_lock_free() {
        for steal in [false, true] {
            let g = chol(8, 3);
            let total = g.total_tasks().unwrap();
            let r = Cluster::run(
                g,
                ClusterConfig::default()
                    .with_workers_per_node(2)
                    .with_sched(SchedBackend::Workassist)
                    .with_migrate(if steal {
                        MigrateConfig::default().with_poll_interval_us(50.0)
                    } else {
                        MigrateConfig::disabled()
                    }),
                Arc::new(NullExecutor),
            );
            assert_eq!(r.tasks_total_executed(), total, "steal={steal}");
            let locks: u64 = r.nodes.iter().map(|n| n.sched.lock_acquisitions).sum();
            assert_eq!(locks, 0, "steal={steal}: workassist took a lock");
        }
    }

    /// The crash-stop acceptance scenario in the threaded runtime: an
    /// 8-node Cholesky loses node 2 a third of the way through, the
    /// leader's heartbeat detector confirms the death against the
    /// fabric, the Safra ring is spliced, and lineage recovery re-homes
    /// every unfinished task — the run still completes with every task
    /// executed exactly once among the survivors and zero protocol
    /// residue (the in-run shutdown asserts).
    #[test]
    fn crash_stop_cholesky_recovers_exactly_once() {
        let g = chol(10, 8);
        let total = g.total_tasks().unwrap();
        let cfg = |faults: FaultPlan| {
            ClusterConfig::default()
                .with_workers_per_node(2)
                .with_migrate(MigrateConfig::default().with_poll_interval_us(50.0))
                .with_faults(faults)
        };
        let g2 = g.clone();
        let ex = Arc::new(
            SpinExecutor::new(CostModel::default_calibrated(), 8, move |t| g2.work_units(t))
                .with_time_scale(0.05),
        );
        // Calibrate the crash instant from a fault-free baseline so it
        // always lands mid-run, whatever this machine's speed.
        let base = Cluster::run(g.clone(), cfg(FaultPlan::default()), ex.clone());
        assert_eq!(base.tasks_total_executed(), total);
        let crash_at = (base.makespan_us / 3.0).max(500.0);
        let spec = format!("crash-node=2,crash-at-us={crash_at:.0}");
        let r = Cluster::run(g, cfg(spec.parse().unwrap()), ex);
        assert_eq!(r.tasks_total_executed(), total, "exactly-once among survivors");
        assert_eq!(r.recovery.nodes_crashed, 1);
        assert!(r.recovery.nodes_suspected >= 1, "the detector fired");
        assert_eq!(r.recovery.ring_repairs, 1, "one token splice");
        assert!(r.recovery.tasks_recovered > 0, "lineage re-homed work");
        assert!(r.recovery.detect_latency_us > 0.0);
        for (ix, n) in r.nodes.iter().enumerate() {
            if ix != 2 {
                let q = n.victim_quarantined[2];
                assert_eq!(q, 1, "node {ix}: dead victim quarantined exactly once");
            }
        }
    }

    /// A crash composed with transient drop/dup faults, on the
    /// lock-free workassist backend (its `drain` feeds the recovery
    /// sweep) and an irregular dynamically-placed workload: still
    /// exactly once.
    #[test]
    fn crash_with_transient_faults_still_exactly_once() {
        let g = Arc::new(UtsGraph::new(UtsParams {
            b0: 24,
            m: 4,
            q: 0.3,
            g: 30_000.0,
            seed: 5,
            nodes: 3,
            max_depth: 18,
        }));
        let size = g.tree_size(10_000_000);
        let spec = "crash-node=1,crash-at-us=2000,drop-reply=0.1,dup=0.1";
        let r = Cluster::run(
            g,
            ClusterConfig::default()
                .with_workers_per_node(2)
                .with_sched(SchedBackend::Workassist)
                .with_migrate(MigrateConfig::default().with_poll_interval_us(30.0))
                .with_faults(spec.parse().unwrap()),
            Arc::new(SpinExecutor::new(
                CostModel::default_calibrated(),
                0,
                |_| 30_000.0,
            )),
        );
        assert_eq!(r.tasks_total_executed(), size);
        assert_eq!(r.recovery.nodes_crashed, 1);
    }

    /// A crash scheduled past the makespan never fires: the run is a
    /// plain faulty-fabric run and the recovery telemetry stays zero.
    #[test]
    fn crash_scheduled_after_completion_is_a_no_op() {
        let g = chol(8, 3);
        let total = g.total_tasks().unwrap();
        let r = Cluster::run(
            g,
            ClusterConfig::default()
                .with_workers_per_node(2)
                .with_migrate(MigrateConfig::default().with_poll_interval_us(50.0))
                .with_faults("crash-node=1,crash-at-us=30000000".parse().unwrap()),
            Arc::new(NullExecutor),
        );
        assert_eq!(r.tasks_total_executed(), total);
        assert_eq!(r.recovery.nodes_crashed, 0);
        assert_eq!(r.recovery.nodes_suspected, 0);
        assert_eq!(r.recovery.tasks_recovered, 0);
        assert_eq!(r.recovery.ring_repairs, 0);
    }

    /// Flat topology (explicit or default): every remote pair is
    /// cluster-distance, so the per-tier thief-side counters must book
    /// all steal traffic to the cluster tier and nothing anywhere else,
    /// and the tier sums must reconcile with the flat steal stats.
    #[test]
    fn flat_topology_books_all_steal_traffic_to_cluster_tier() {
        let g = chol(8, 3);
        let total = g.total_tasks().unwrap();
        let r = Cluster::run(
            g,
            ClusterConfig::default()
                .with_workers_per_node(2)
                .with_migrate(MigrateConfig::default().with_poll_interval_us(50.0))
                .with_topology(Topology::flat())
                .with_steal_domains(StealDomains::Flat),
            Arc::new(NullExecutor),
        );
        assert_eq!(r.tasks_total_executed(), total);
        for (ix, n) in r.nodes.iter().enumerate() {
            assert_eq!(
                n.tier_steal_requests[0] + n.tier_steal_requests[1],
                0,
                "node {ix}: flat runs must never see a sub-cluster tier"
            );
            assert_eq!(n.tier_steal_requests[2], n.steal.requests_sent);
            assert_eq!(
                n.tier_steal_grants.iter().sum::<u64>(),
                n.steal.successful_steals
            );
        }
    }

    /// `--steal-domains hierarchical` on a 2-tier topology in the
    /// threaded runtime: the run completes exactly once on every
    /// backend path touched (escalation, domain-scoped picks, per-pair
    /// timeouts), the per-tier counters reconcile with the steal stats,
    /// and thieves provably begin at their home socket tier.
    #[test]
    fn hierarchical_domains_two_tier_threaded_run_completes() {
        let g = Arc::new(UtsGraph::new(UtsParams {
            b0: 24,
            m: 4,
            q: 0.3,
            g: 30_000.0,
            seed: 5,
            nodes: 4,
            max_depth: 18,
        }));
        let size = g.tree_size(10_000_000);
        let topo = Topology::two_tier(
            2,
            LinkModel {
                latency_us: 1.0,
                bw_bytes_per_us: 40_000.0,
            },
            LinkModel {
                latency_us: 20.0,
                bw_bytes_per_us: 2_500.0,
            },
        );
        let r = Cluster::run(
            g,
            ClusterConfig::default()
                .with_workers_per_node(2)
                .with_migrate(MigrateConfig::default().with_poll_interval_us(30.0))
                .with_topology(topo)
                .with_steal_domains(StealDomains::Hierarchical),
            Arc::new(SpinExecutor::new(CostModel::default_calibrated(), 0, |_| {
                30_000.0
            })),
        );
        assert_eq!(r.tasks_total_executed(), size);
        let mut requests = 0;
        for (ix, n) in r.nodes.iter().enumerate() {
            assert_eq!(
                n.tier_steal_requests.iter().sum::<u64>(),
                n.steal.requests_sent,
                "node {ix}: tier requests reconcile"
            );
            assert_eq!(
                n.tier_steal_grants.iter().sum::<u64>(),
                n.steal.successful_steals,
                "node {ix}: tier grants reconcile"
            );
            requests += n.steal.requests_sent;
        }
        assert!(requests > 0, "the starving sockets must have stolen");
        // Every thief's escalation starts at its socket tier, so the
        // socket tier must have seen traffic before any widening.
        let near: u64 = r.nodes.iter().map(|n| n.tier_steal_requests[0]).sum();
        assert!(near > 0, "hierarchical thieves begin at the socket tier");
    }

    #[test]
    fn builder_setters_equal_exhaustive_literal() {
        // The one place a full ClusterConfig literal is allowed to
        // live: the builders' own equivalence check.
        let topo: Topology = "socket=2,rack=4,rack-lat-us=9".parse().unwrap();
        let faults: FaultPlan = "dup=0.2".parse().unwrap();
        let link = LinkModel {
            latency_us: 3.0,
            bw_bytes_per_us: 750.0,
        };
        let migrate = MigrateConfig::default().with_poll_interval_us(42.0);
        let built = ClusterConfig::default()
            .with_workers_per_node(5)
            .with_link(link)
            .with_migrate(migrate)
            .with_seed(11)
            .with_record_polls(false)
            .with_sched(SchedBackend::Workassist)
            .with_batch_activations(false)
            .with_pool_floor(6)
            .with_faults(faults)
            .with_topology(topo)
            .with_steal_domains(StealDomains::Hierarchical);
        let literal = ClusterConfig {
            workers_per_node: 5,
            link,
            migrate,
            seed: 11,
            record_polls: false,
            sched: SchedBackend::Workassist,
            batch_activations: false,
            pool_floor: 6,
            faults,
            topology: topo,
            steal_domains: StealDomains::Hierarchical,
        };
        assert_eq!(built, literal);
    }
}
