//! Property-based invariant tests (in-tree `prop` driver): randomized
//! DAGs, cluster geometries and policies must never violate the
//! runtime's core guarantees.

use std::sync::Arc;

use parsteal::comm::LinkModel;
use parsteal::dataflow::task::TaskDesc;
use parsteal::dataflow::ttg::TaskGraph;
use parsteal::faults::FaultPlan;
use parsteal::migrate::{
    ExecSnapshot, MigrateConfig, ThiefPolicy, VictimOutcome, VictimPolicy, VictimSelect,
    VictimSelector,
};
use parsteal::node::{Cluster, ClusterConfig, NullExecutor};
use parsteal::prop_assert;
use parsteal::sched::{SchedBackend, SchedQueue, TaskMeta};
use parsteal::sim::{CostModel, SimConfig, Simulator};
use parsteal::topology::{StealDomains, Topology, TIER_COUNT};
use parsteal::util::prop::{check, Config};
use parsteal::util::rng::Rng;
use parsteal::workloads::{CholeskyGraph, CholeskyParams, UtsGraph, UtsParams};

fn random_migrate(rng: &mut Rng) -> MigrateConfig {
    // Builder order mirrors the old field order so the RNG draw
    // sequence (and thus every replayed case) is unchanged.
    MigrateConfig::default()
        .with_enabled(rng.uniform() < 0.8)
        .with_thief(if rng.uniform() < 0.5 {
            ThiefPolicy::ReadyOnly
        } else {
            ThiefPolicy::ReadySuccessors
        })
        .with_victim(match rng.below(3) {
            0 => VictimPolicy::Half,
            1 => VictimPolicy::Chunk(1 + rng.below(30) as usize),
            _ => VictimPolicy::Single,
        })
        .with_use_waiting_time(rng.uniform() < 0.5)
        .with_poll_interval_us(10.0 + rng.uniform() * 200.0)
        .with_max_inflight(1 + rng.below(3) as usize)
        .with_migrate_overhead_us(rng.uniform() * 300.0)
        .with_exec_ewma(rng.uniform() < 0.5)
        .with_exec_per_class(rng.uniform() < 0.5)
        .with_share_estimates(rng.uniform() < 0.5)
        .with_victim_select(if rng.uniform() < 0.5 {
            VictimSelect::Uniform
        } else {
            VictimSelect::Targeted
        })
}

/// Uniformly random scheduler backend: every invariant in this file
/// must hold on the full matrix (central / sharded / workassist).
fn random_sched(rng: &mut Rng) -> SchedBackend {
    let n = SchedBackend::ALL.len() as u64;
    SchedBackend::ALL[rng.below(n) as usize]
}

/// Exactly-once execution and full completion for random Cholesky
/// geometries under random policies.
#[test]
fn prop_cholesky_sim_executes_every_task_once() {
    check(
        "cholesky-exactly-once",
        Config {
            cases: 30,
            max_size: 16,
            seed: 0xA11CE,
        },
        |rng, size| {
            let tiles = 2 + size as u32;
            let nodes = 1 + rng.below(5) as u32;
            let graph = Arc::new(CholeskyGraph::new(CholeskyParams {
                tiles,
                tile_size: 8 + 8 * rng.below(4) as u32,
                nodes,
                dense_fraction: rng.uniform(),
                seed: rng.next_u64(),
                all_dense: false,
            }));
            let total = graph.total_tasks().unwrap();
            let report = Simulator::new(
                graph,
                SimConfig::default()
                    .with_workers_per_node(1 + rng.below(8) as usize)
                    .with_link(LinkModel {
                        latency_us: rng.uniform() * 20.0,
                        bw_bytes_per_us: 100.0 + rng.uniform() * 1e4,
                    })
                    .with_seed(rng.next_u64())
                    .with_max_events(200_000_000)
                    .with_record_polls(false)
                    .with_sched(random_sched(rng))
                    .with_batch_activations(rng.uniform() < 0.5)
                    .with_pool_floor(rng.below(4) as usize),
                CostModel::default_calibrated(),
                random_migrate(rng),
                16,
            )
            .run();
            prop_assert!(
                report.tasks_total_executed() == total,
                "executed {} of {total}",
                report.tasks_total_executed()
            );
            prop_assert!(report.makespan_us > 0.0, "zero makespan");
            Ok(())
        },
    );
}

/// UTS: the simulator must execute exactly the deterministic tree size,
/// no matter how tasks migrate.
#[test]
fn prop_uts_sim_matches_tree_size() {
    check(
        "uts-tree-size",
        Config {
            cases: 20,
            max_size: 24,
            seed: 0xB0B,
        },
        |rng, size| {
            let graph = Arc::new(UtsGraph::new(UtsParams {
                b0: 2 + size as u32,
                m: 2 + rng.below(4) as u32,
                q: 0.1 + rng.uniform() * 0.25,
                g: 100.0 + rng.uniform() * 5_000.0,
                seed: rng.next_u64(),
                nodes: 1 + rng.below(4) as u32,
                max_depth: 10 + rng.below(8) as u32,
            }));
            let size = graph.tree_size(5_000_000);
            if size >= 5_000_000 {
                return Ok(()); // skip pathological trees
            }
            let report = Simulator::new(
                graph,
                SimConfig::default()
                    .with_workers_per_node(1 + rng.below(4) as usize)
                    .with_seed(rng.next_u64())
                    .with_max_events(200_000_000)
                    .with_record_polls(false)
                    .with_sched(random_sched(rng))
                    .with_batch_activations(rng.uniform() < 0.5)
                    .with_pool_floor(rng.below(4) as usize),
                CostModel::default_calibrated(),
                random_migrate(rng),
                0,
            )
            .run();
            prop_assert!(
                report.tasks_total_executed() == size,
                "executed {} of tree {size}",
                report.tasks_total_executed()
            );
            Ok(())
        },
    );
}

/// Scheduler invariant: any interleaving of inserts, selects and steal
/// extractions conserves tasks (nothing lost, nothing duplicated).
#[test]
fn prop_sched_queue_conserves_tasks() {
    use parsteal::dataflow::task::TaskClass;
    check(
        "sched-conservation",
        Config {
            cases: 80,
            max_size: 400,
            seed: 0x5EED,
        },
        |rng, size| {
            let q = SchedQueue::new();
            let mut inserted = std::collections::HashSet::new();
            let mut removed = std::collections::HashSet::new();
            let mut next_id = 0u32;
            for _ in 0..size {
                match rng.below(4) {
                    0 | 1 => {
                        let t = TaskDesc::indexed(TaskClass::Synthetic, next_id, 0, 0);
                        next_id += 1;
                        q.insert(t, rng.next_u64() as i64 % 1000);
                        inserted.insert(t);
                    }
                    2 => {
                        if let Some(t) = q.select() {
                            prop_assert!(removed.insert(t), "duplicate select of {t}");
                        }
                    }
                    _ => {
                        for t in q.extract_for_steal(rng.below(5) as usize, |t| t.i % 3 != 0) {
                            prop_assert!(t.i % 3 != 0, "filter violated");
                            prop_assert!(removed.insert(t), "duplicate steal of {t}");
                        }
                    }
                }
            }
            while let Some(t) = q.select() {
                prop_assert!(removed.insert(t), "duplicate drain of {t}");
            }
            prop_assert!(
                inserted == removed,
                "conservation violated: {} in, {} out",
                inserted.len(),
                removed.len()
            );
            Ok(())
        },
    );
}

/// Cholesky DAG structural invariant on random sizes: edge counts from
/// `successors` equal declared `in_degree` for every reachable task.
#[test]
fn prop_cholesky_dag_consistent() {
    use std::collections::{HashMap, HashSet};
    check(
        "cholesky-dag-consistency",
        Config {
            cases: 12,
            max_size: 14,
            seed: 0xDA6,
        },
        |rng, size| {
            let graph = CholeskyGraph::new(CholeskyParams {
                tiles: 1 + size as u32,
                tile_size: 8,
                nodes: 1 + rng.below(6) as u32,
                dense_fraction: rng.uniform(),
                seed: rng.next_u64(),
                all_dense: false,
            });
            let mut incoming: HashMap<TaskDesc, u32> = HashMap::new();
            let mut seen = HashSet::new();
            let mut stack = graph.roots();
            while let Some(t) = stack.pop() {
                if !seen.insert(t) {
                    continue;
                }
                for s in graph.successors(t) {
                    *incoming.entry(s).or_insert(0) += 1;
                    stack.push(s);
                }
            }
            prop_assert!(
                seen.len() as u64 == graph.total_tasks().unwrap(),
                "reachable {} != total {}",
                seen.len(),
                graph.total_tasks().unwrap()
            );
            for t in &seen {
                let want = graph.in_degree(*t);
                let got = incoming.get(t).copied().unwrap_or(0);
                prop_assert!(got == want, "{t}: in-degree {want} but {got} edges");
            }
            Ok(())
        },
    );
}

/// Victim-policy allowance bounds: extraction never exceeds the policy
/// bound nor takes non-stealable tasks.
#[test]
fn prop_victim_allowance_bounds() {
    use parsteal::migrate::protocol::decide_steal;
    check(
        "victim-allowance",
        Config {
            cases: 60,
            max_size: 200,
            seed: 0xFEE,
        },
        |rng, size| {
            let graph = Arc::new(CholeskyGraph::new(CholeskyParams {
                tiles: 24,
                tile_size: 16,
                nodes: 2,
                dense_fraction: rng.uniform(),
                seed: rng.next_u64(),
                all_dense: false,
            }));
            let q = SchedQueue::new();
            let mut stealable = 0usize;
            for i in 1..=(size as u32) {
                let t = CholeskyGraph::gemm(i % 23 + 1, i % (i % 23 + 1).max(1), 0);
                if graph.is_stealable(t) {
                    stealable += 1;
                }
                // The runtime contract: enqueue with the graph's meta so
                // the incremental accounting sees the stealable bit.
                q.insert_meta(t, i as i64, TaskMeta::of(graph.as_ref(), t));
            }
            let mc = random_migrate(rng);
            if !mc.enabled {
                return Ok(());
            }
            let before = q.len();
            let est = ExecSnapshot::uniform(50.0);
            let d = decide_steal(&mc, graph.as_ref(), &q, 8, &est, 5.0, 1e4);
            let bound = match mc.victim {
                VictimPolicy::Half => stealable / 2,
                VictimPolicy::Chunk(k) => k.min(stealable),
                VictimPolicy::Single => 1.min(stealable),
            };
            prop_assert!(
                d.tasks.len() <= bound,
                "extracted {} > bound {bound} ({:?})",
                d.tasks.len(),
                mc.victim
            );
            for t in &d.tasks {
                prop_assert!(graph.is_stealable(*t), "non-stealable task migrated");
            }
            prop_assert!(
                q.len() + d.tasks.len() == before,
                "queue conservation violated"
            );
            Ok(())
        },
    );
}

/// The `--share-estimates` merge rule is order-insensitive: merging the
/// same set of victim digest entries into a thief's table in any order
/// lands on the same estimate (within f64 tolerance) and exactly the
/// same sample count — so which reply arrives first cannot bias the
/// gate. Also pins the two absorbing cases: zero-sample entries are
/// no-ops in any position, and the first seeded entry is an adoption.
#[test]
fn prop_digest_merge_is_order_insensitive() {
    use parsteal::migrate::merge_estimate;
    check(
        "digest-merge-order-insensitive",
        Config {
            cases: 80,
            max_size: 12,
            seed: 0xD16E57,
        },
        |rng, size| {
            let entries: Vec<(f64, u64)> = (0..size.max(2))
                .map(|_| {
                    if rng.uniform() < 0.2 {
                        (0.0, 0) // unseeded entry: must merge as a no-op
                    } else {
                        (1.0 + rng.uniform() * 5_000.0, 1 + rng.below(50))
                    }
                })
                .collect();
            let merge_all = |order: &[usize]| -> (f64, u64) {
                let mut est = 0.0;
                let mut n = 0u64;
                for &ix in order {
                    let (e, s) = entries[ix];
                    let (m, mn) = merge_estimate(est, n, e, s);
                    est = m;
                    n = mn;
                }
                (est, n)
            };
            let forward: Vec<usize> = (0..entries.len()).collect();
            let mut shuffled = forward.clone();
            // Fisher-Yates with the prop RNG.
            for i in (1..shuffled.len()).rev() {
                let j = rng.below(i as u64 + 1) as usize;
                shuffled.swap(i, j);
            }
            let (a, an) = merge_all(&forward);
            let (b, bn) = merge_all(&shuffled);
            prop_assert!(an == bn, "sample counts must merge exactly: {an} vs {bn}");
            let scale = a.abs().max(b.abs()).max(1.0);
            prop_assert!(
                (a - b).abs() <= 1e-9 * scale,
                "merged estimate depends on order: {a} vs {b}"
            );
            // The weighted blend never leaves the convex hull of the
            // seeded entries.
            let seeded: Vec<f64> = entries
                .iter()
                .filter(|(_, n)| *n > 0)
                .map(|(e, _)| *e)
                .collect();
            if seeded.is_empty() {
                prop_assert!(an == 0 && a == 0.0, "no seed -> still unseeded");
            } else {
                let lo = seeded.iter().cloned().fold(f64::INFINITY, f64::min);
                let hi = seeded.iter().cloned().fold(0.0, f64::max);
                prop_assert!(
                    a >= lo - 1e-9 * scale && a <= hi + 1e-9 * scale,
                    "blend {a} escaped [{lo}, {hi}]"
                );
                prop_assert!(
                    an == entries.iter().map(|(_, n)| n).sum::<u64>(),
                    "samples must sum over seeded entries"
                );
            }
            Ok(())
        },
    );
}

/// CLI-surface drift guard: every policy label the code can print must
/// parse back to the same policy, across every accepted spelling of the
/// chunk size (`chunk`, `chunk8`, `chunk(8)`, `chunk=8`, `chunk-8`) —
/// so the README, `--help` text and the parser cannot diverge.
#[test]
fn prop_policy_label_fromstr_round_trip() {
    check(
        "policy-label-roundtrip",
        Config {
            cases: 96,
            max_size: 4096,
            seed: 0x1ABE1,
        },
        |rng, size| {
            let k = 1 + rng.below(size as u64) as usize;
            for victim in [
                VictimPolicy::Half,
                VictimPolicy::Single,
                VictimPolicy::Chunk(k),
            ] {
                let label = victim.label();
                let parsed = label
                    .parse::<VictimPolicy>()
                    .map_err(|e| format!("label '{label}' did not parse: {e}"))?;
                prop_assert!(
                    parsed == victim,
                    "label '{label}' round-tripped to {parsed:?}"
                );
            }
            for spelling in [
                format!("chunk{k}"),
                format!("chunk({k})"),
                format!("chunk={k}"),
                format!("chunk-{k}"),
                format!("Chunk({k})"),
            ] {
                let parsed = spelling
                    .parse::<VictimPolicy>()
                    .map_err(|e| format!("spelling '{spelling}' did not parse: {e}"))?;
                prop_assert!(
                    parsed == VictimPolicy::Chunk(k),
                    "'{spelling}' parsed to {parsed:?}, wanted Chunk({k})"
                );
            }
            // Bare "chunk" is the paper's default chunk of 20.
            prop_assert!(
                "chunk".parse::<VictimPolicy>() == Ok(VictimPolicy::Chunk(20)),
                "bare 'chunk' must mean Chunk(20)"
            );
            for thief in [ThiefPolicy::ReadyOnly, ThiefPolicy::ReadySuccessors] {
                let label = thief.label();
                let parsed = label
                    .parse::<ThiefPolicy>()
                    .map_err(|e| format!("label '{label}' did not parse: {e}"))?;
                prop_assert!(
                    parsed == thief,
                    "label '{label}' round-tripped to {parsed:?}"
                );
            }
            for select in [VictimSelect::Uniform, VictimSelect::Targeted] {
                let label = select.label();
                let parsed = label
                    .parse::<VictimSelect>()
                    .map_err(|e| format!("label '{label}' did not parse: {e}"))?;
                prop_assert!(
                    parsed == select,
                    "label '{label}' round-tripped to {parsed:?}"
                );
            }
            for (spelling, want) in [
                ("random", VictimSelect::Uniform),
                ("rand", VictimSelect::Uniform),
                ("UNIFORM", VictimSelect::Uniform),
                ("target", VictimSelect::Targeted),
                ("scored", VictimSelect::Targeted),
                ("Targeted", VictimSelect::Targeted),
            ] {
                let parsed = spelling
                    .parse::<VictimSelect>()
                    .map_err(|e| format!("spelling '{spelling}' did not parse: {e}"))?;
                prop_assert!(
                    parsed == want,
                    "'{spelling}' parsed to {parsed:?}, wanted {want:?}"
                );
            }
            prop_assert!(
                "nearest".parse::<VictimSelect>().is_err(),
                "unknown selection spellings must be rejected"
            );
            // `--sched` backend labels round-trip too, including the
            // workassist aliases the CLI accepts.
            for backend in SchedBackend::ALL {
                let label = backend.label();
                let parsed = label
                    .parse::<SchedBackend>()
                    .map_err(|e| format!("label '{label}' did not parse: {e}"))?;
                prop_assert!(
                    parsed == backend,
                    "label '{label}' round-tripped to {parsed:?}"
                );
            }
            for (spelling, want) in [
                ("workassist", SchedBackend::Workassist),
                ("lockfree", SchedBackend::Workassist),
                ("assist", SchedBackend::Workassist),
                ("CENTRAL", SchedBackend::Central),
                ("Sharded", SchedBackend::Sharded),
            ] {
                let parsed = spelling
                    .parse::<SchedBackend>()
                    .map_err(|e| format!("spelling '{spelling}' did not parse: {e}"))?;
                prop_assert!(
                    parsed == want,
                    "'{spelling}' parsed to {parsed:?}, wanted {want:?}"
                );
            }
            prop_assert!(
                "lockless".parse::<SchedBackend>().is_err(),
                "unknown backend spellings must be rejected"
            );
            // `--steal-domains` labels round-trip too, including the
            // short alias the CLI accepts.
            for domains in [StealDomains::Flat, StealDomains::Hierarchical] {
                let label = domains.label();
                let parsed = label
                    .parse::<StealDomains>()
                    .map_err(|e| format!("label '{label}' did not parse: {e}"))?;
                prop_assert!(
                    parsed == domains,
                    "label '{label}' round-tripped to {parsed:?}"
                );
            }
            prop_assert!(
                "hier".parse::<StealDomains>() == Ok(StealDomains::Hierarchical),
                "'hier' is the accepted short spelling"
            );
            prop_assert!(
                "nested".parse::<StealDomains>().is_err(),
                "unknown domain spellings must be rejected"
            );
            Ok(())
        },
    );
}

/// CLI-surface drift guard for `--topology`: every spec the code can
/// print must parse back to the same topology, over random tier sizes
/// and link parameters (mirrors the policy-label round-trip above) —
/// and the tier map the parsed topology induces is sane: self is
/// always nearest, tiers are symmetric and in range.
#[test]
fn prop_topology_label_round_trips() {
    check(
        "topology-label-roundtrip",
        Config {
            cases: 120,
            max_size: 16,
            seed: 0x7090,
        },
        |rng, _| {
            let mut topo = Topology::flat();
            if rng.uniform() < 0.8 {
                let socket = 2 + rng.below(6) as u32;
                topo.socket_size = socket;
                if rng.uniform() < 0.5 {
                    // Nesting constraint: racks are whole sockets.
                    topo.rack_size = socket * (2 + rng.below(3) as u32);
                }
            }
            if rng.uniform() < 0.7 {
                topo.socket_lat_us = rng.uniform() * 10.0;
            }
            if rng.uniform() < 0.7 {
                topo.socket_bw = 100.0 + rng.uniform() * 50_000.0;
            }
            if rng.uniform() < 0.5 {
                topo.rack_lat_us = rng.uniform() * 20.0;
            }
            if rng.uniform() < 0.5 {
                topo.rack_bw = 100.0 + rng.uniform() * 20_000.0;
            }
            if rng.uniform() < 0.5 {
                topo.cluster_lat_us = rng.uniform() * 40.0;
            }
            if rng.uniform() < 0.5 {
                topo.cluster_bw = 100.0 + rng.uniform() * 10_000.0;
            }
            let label = topo.label();
            let parsed: Topology = label
                .parse()
                .map_err(|e| format!("label '{label}' did not parse: {e}"))?;
            prop_assert!(
                parsed == topo,
                "label '{label}' round-tripped to {parsed:?}, wanted {topo:?}"
            );
            prop_assert!(
                topo.is_flat() == (topo == Topology::flat()),
                "is_flat must agree with equality against the default"
            );
            let n = 2 + rng.below(30) as usize;
            for a in 0..n {
                prop_assert!(parsed.tier_of(a, a) == 0, "self must be nearest");
                for b in 0..n {
                    let t = parsed.tier_of(a, b);
                    prop_assert!(t < TIER_COUNT, "tier out of range");
                    prop_assert!(
                        t == parsed.tier_of(b, a),
                        "tier_of must be symmetric ({a},{b})"
                    );
                }
            }
            Ok(())
        },
    );
}

/// The tentpole's pricing contract from the other side: a topology
/// whose tier links all equal the base link prices every pair exactly
/// like the flat fabric, so the DES must be byte-identical between the
/// two — same makespan, same event counts, same steal totals — over
/// random geometries and policies. (The explicit `--topology flat`
/// case is pinned by the engine's unit tests.)
#[test]
fn prop_uniform_topology_is_byte_identical_to_flat() {
    check(
        "uniform-topology-identical",
        Config {
            cases: 8,
            max_size: 10,
            seed: 0x70F1A7,
        },
        |rng, size| {
            let params = CholeskyParams {
                tiles: 4 + size as u32,
                tile_size: 16,
                nodes: 2 + rng.below(4) as u32,
                dense_fraction: rng.uniform(),
                seed: rng.next_u64(),
                all_dense: false,
            };
            let mc = random_migrate(rng);
            let seed = rng.next_u64();
            let workers = 1 + rng.below(4) as usize;
            let base = LinkModel::cluster();
            let run = |topo: Topology| {
                Simulator::new(
                    Arc::new(CholeskyGraph::new(params.clone())),
                    SimConfig::default()
                        .with_workers_per_node(workers)
                        .with_seed(seed)
                        .with_max_events(200_000_000)
                        .with_record_polls(false)
                        .with_topology(topo),
                    CostModel::default_calibrated(),
                    mc,
                    16,
                )
                .run()
            };
            let flat = run(Topology::flat());
            let uniform = run(Topology::two_tier(2, base, base));
            prop_assert!(
                flat.makespan_us == uniform.makespan_us,
                "makespan diverged: {} vs {}",
                flat.makespan_us,
                uniform.makespan_us
            );
            prop_assert!(
                flat.events == uniform.events
                    && flat.deliver_events == uniform.deliver_events,
                "event counts diverged: {}/{} vs {}/{}",
                flat.events,
                flat.deliver_events,
                uniform.events,
                uniform.deliver_events
            );
            let (a, b) = (flat.total_steals(), uniform.total_steals());
            prop_assert!(
                a.requests_sent == b.requests_sent
                    && a.successful_steals == b.successful_steals
                    && a.tasks_migrated == b.tasks_migrated,
                "steal totals diverged"
            );
            Ok(())
        },
    );
}

/// Builder-built configs are exactly their field assignments: random
/// knob draws pushed through the chainable setters land verbatim in
/// the public fields of every config type the API redesign touched,
/// and both RunConfig projections carry the shared knobs through.
/// (The exhaustive builder-vs-literal equivalences live in each
/// module's own unit tests — the only literal sites left.)
#[test]
fn prop_builders_set_exactly_their_fields() {
    use parsteal::config::RunConfig;
    check(
        "builders-set-fields",
        Config {
            cases: 60,
            max_size: 8,
            seed: 0xB111D,
        },
        |rng, _| {
            let mc = random_migrate(rng);
            let workers = 1 + rng.below(64) as usize;
            let seed = rng.next_u64();
            let sched = random_sched(rng);
            let batch = rng.uniform() < 0.5;
            let floor = rng.below(8) as usize;
            let link = LinkModel {
                latency_us: rng.uniform() * 20.0,
                bw_bytes_per_us: 100.0 + rng.uniform() * 1e4,
            };
            let domains = if rng.uniform() < 0.5 {
                StealDomains::Flat
            } else {
                StealDomains::Hierarchical
            };
            let topo = Topology::two_tier(2 + rng.below(6) as u32, link, LinkModel::cluster());

            let sim = SimConfig::default()
                .with_workers_per_node(workers)
                .with_link(link)
                .with_seed(seed)
                .with_sched(sched)
                .with_batch_activations(batch)
                .with_pool_floor(floor)
                .with_topology(topo)
                .with_steal_domains(domains);
            prop_assert!(
                sim.workers_per_node == workers
                    && sim.link == link
                    && sim.seed == seed
                    && sim.sched == sched
                    && sim.batch_activations == batch
                    && sim.pool_floor == floor
                    && sim.topology == topo
                    && sim.steal_domains == domains,
                "SimConfig setters must land verbatim"
            );

            let cl = ClusterConfig::default()
                .with_workers_per_node(workers)
                .with_link(link)
                .with_migrate(mc)
                .with_seed(seed)
                .with_sched(sched)
                .with_batch_activations(batch)
                .with_pool_floor(floor)
                .with_topology(topo)
                .with_steal_domains(domains);
            prop_assert!(
                cl.workers_per_node == workers
                    && cl.link == link
                    && cl.migrate == mc
                    && cl.seed == seed
                    && cl.sched == sched
                    && cl.batch_activations == batch
                    && cl.pool_floor == floor
                    && cl.topology == topo
                    && cl.steal_domains == domains,
                "ClusterConfig setters must land verbatim"
            );

            let rc = RunConfig::default()
                .with_workers_per_node(workers)
                .with_link(link)
                .with_migrate(mc)
                .with_seed(seed)
                .with_sched(sched)
                .with_batch_activations(batch)
                .with_pool_floor(floor)
                .with_topology(topo)
                .with_steal_domains(domains);
            prop_assert!(
                rc.workers_per_node == workers
                    && rc.link == link
                    && rc.migrate == mc
                    && rc.seed == seed
                    && rc.sched == sched
                    && rc.batch_activations == batch
                    && rc.pool_floor == floor
                    && rc.topology == topo
                    && rc.steal_domains == domains,
                "RunConfig setters must land verbatim"
            );
            let sc = rc.sim_config();
            prop_assert!(
                sc.workers_per_node == workers
                    && sc.link == link
                    && sc.sched == sched
                    && sc.topology == topo
                    && sc.steal_domains == domains,
                "sim_config must carry the shared knobs"
            );
            let cc = rc.cluster_config();
            prop_assert!(
                cc.workers_per_node == workers
                    && cc.link == link
                    && cc.migrate == mc
                    && cc.sched == sched
                    && cc.topology == topo
                    && cc.steal_domains == domains,
                "cluster_config must carry the shared knobs"
            );
            Ok(())
        },
    );
}

/// Targeted victim selection is a pure function of its history: feeding
/// two selectors the same random reply sequence gives identical scores
/// and identical greedy picks, and fading the history to zero returns
/// the selector to the uniform regime — every candidate scores the same
/// and repeated picks cover all of them (the paper's protocol as the
/// fixed point of full decay).
#[test]
fn prop_victim_selector_deterministic_and_decays_to_uniform() {
    use parsteal::util::rng::thief_rng;
    check(
        "victim-selector-determinism",
        Config {
            cases: 60,
            max_size: 120,
            seed: 0x7A26E7,
        },
        |rng, size| {
            let n = 2 + rng.below(7) as usize;
            let node = rng.below(n as u64) as usize;
            let seed = rng.next_u64();
            let mk = || {
                VictimSelector::new(node, n, thief_rng(seed, node))
                    .with_link(rng_free_latency(), 1_000.0)
                    .with_epsilon(0.0)
            };
            let mut a = mk();
            let mut b = mk();
            let fallback = 1.0 + rng.uniform() * 500.0;
            for _ in 0..size.max(1) {
                let victim = {
                    // Any candidate but the thief itself.
                    let r = rng.below(n as u64 - 1) as usize;
                    if r >= node { r + 1 } else { r }
                };
                let outcome = match rng.below(3) {
                    0 => VictimOutcome::Granted,
                    1 => VictimOutcome::DeniedWaitingTime,
                    _ => VictimOutcome::DeniedEmpty,
                };
                let digest = (rng.uniform() < 0.5).then(|| 1.0 + rng.uniform() * 2_000.0);
                a.record(victim, outcome, digest);
                b.record(victim, outcome, digest);
            }
            for v in (0..n).filter(|v| *v != node) {
                let (sa, sb) = (a.score(v, fallback), b.score(v, fallback));
                prop_assert!(
                    sa == sb,
                    "identical history, different scores for {v}: {sa} vs {sb}"
                );
            }
            for _ in 0..10 {
                let (pa, pb) = (a.pick(fallback), b.pick(fallback));
                prop_assert!(pa == pb, "identical history, different picks: {pa} vs {pb}");
                prop_assert!(pa != node, "picked itself");
            }
            // Full decay: back to the uniform regime.
            a.fade(0.0);
            let candidates: Vec<usize> = (0..n).filter(|v| *v != node).collect();
            let base = a.score(candidates[0], fallback);
            for &v in &candidates {
                prop_assert!(
                    a.score(v, fallback) == base,
                    "faded selector must score all candidates equally"
                );
            }
            let mut seen = vec![false; n];
            for _ in 0..64 * n {
                let v = a.pick(fallback);
                prop_assert!(v != node, "faded pick chose itself");
                seen[v] = true;
            }
            for &v in &candidates {
                prop_assert!(seen[v], "faded picks must cover victim {v} (uniform draw)");
            }
            Ok(())
        },
    );
}

/// Uniform link price for the determinism property: a constant, so the
/// two selectors under comparison share it by construction.
fn rng_free_latency() -> f64 {
    5.0
}

/// A random *finite* chaos schedule: aggressive enough that replies are
/// lost and duplicated in most runs, but every probability stays under
/// the parser's convergence cap and every straggler window closes, so
/// the retransmit loops are guaranteed to drain.
fn random_fault_plan(rng: &mut Rng) -> FaultPlan {
    let mut plan = FaultPlan {
        enabled: true,
        drop_request: rng.uniform() * 0.3,
        drop_reply: 0.15 + rng.uniform() * 0.25,
        drop_ack: rng.uniform() * 0.3,
        dup_request: rng.uniform() * 0.25,
        dup_reply: 0.1 + rng.uniform() * 0.2,
        dup_ack: rng.uniform() * 0.25,
        ..Default::default()
    };
    if rng.uniform() < 0.5 {
        plan.delay_factor = 1.0 + rng.uniform() * 3.0;
        plan.delay_p = rng.uniform() * 0.9;
    }
    if rng.uniform() < 0.3 {
        plan.slow_node = Some(rng.below(4) as u32);
        plan.slow_factor = 1.0 + rng.uniform() * 4.0;
        plan.slow_from_us = rng.uniform() * 5_000.0;
        // Finite by construction: an unbounded stall reads as a crash
        // — the detector quarantines the node permanently (covered by
        // the crash-stop sweep below); this sweep asserts that
        // *transient* chaos heals without abandoning anyone.
        plan.slow_until_us = plan.slow_from_us + 1_000.0 + rng.uniform() * 20_000.0;
        plan.stall = rng.uniform() < 0.5;
    }
    plan
}

/// Chaos property: random fault schedules on random UTS trees under
/// random steal policies still execute every task exactly once, and the
/// self-healing machinery is actually exercised — across the sweep the
/// protocol must observe timeouts, retries, ledger reclaims and
/// suppressed duplicate replies (the DES itself asserts zero ledger
/// residue and `inflight_steals == 0` at the end of every run).
#[test]
fn prop_steal_protocol_heals_under_chaos() {
    let mut agg = (0u64, 0u64, 0u64, 0u64); // timeouts, retries, reclaims, dups
    check(
        "chaos-exactly-once",
        Config {
            cases: 12,
            max_size: 24,
            seed: 0xC4A05,
        },
        |rng, size| {
            let plan = random_fault_plan(rng);
            let graph = Arc::new(UtsGraph::new(UtsParams {
                b0: 16 + size as u32,
                m: 4,
                q: 0.25 + rng.uniform() * 0.1,
                g: 20_000.0 + rng.uniform() * 30_000.0,
                seed: rng.next_u64(),
                nodes: 2 + rng.below(3) as u32,
                max_depth: 20,
            }));
            let tree = graph.tree_size(300_000);
            if tree >= 300_000 {
                return Ok(()); // skip pathological trees
            }
            let mut mc = random_migrate(rng);
            mc.enabled = true;
            mc.poll_interval_us = 15.0 + rng.uniform() * 30.0;
            let report = Simulator::new(
                graph,
                SimConfig::default()
                    .with_workers_per_node(2 + rng.below(3) as usize)
                    .with_seed(rng.next_u64())
                    .with_max_events(200_000_000)
                    .with_record_polls(false)
                    .with_sched(random_sched(rng))
                    .with_batch_activations(rng.uniform() < 0.5)
                    .with_pool_floor(rng.below(4) as usize)
                    .with_faults(plan),
                CostModel::default_calibrated(),
                mc,
                0,
            )
            .run();
            prop_assert!(
                report.tasks_total_executed() == tree,
                "plan '{}': executed {} of tree {tree}",
                plan.label(),
                report.tasks_total_executed()
            );
            agg.0 += report.steal_timeouts_total();
            agg.1 += report.steal_retries_total();
            agg.2 += report.ledger_reclaims_total();
            agg.3 += report.dup_replies_suppressed_total();
            Ok(())
        },
    );
    // The sweep as a whole must have healed something, or the chaos
    // schedules above are too tame to mean anything.
    assert!(agg.0 > 0, "no steal timeouts observed across the sweep");
    assert!(agg.1 > 0, "no retries observed across the sweep");
    assert!(agg.2 > 0, "no ledger reclaims observed across the sweep");
    assert!(agg.3 > 0, "no duplicate replies suppressed across the sweep");
}

/// The threaded runtime under the same chaos schedules, crossed with
/// every scheduler backend: every task still executes exactly once
/// (the cluster's shutdown drain asserts `inflight_steals == 0` and an
/// empty transfer ledger internally). The workassist arm is the
/// self-healing steal protocol running on the lock-free queue — the
/// composition this PR promises.
#[test]
fn chaos_threaded_runtime_heals_exactly_once() {
    for backend in SchedBackend::ALL {
        for (spec, seed) in [
            ("drop=0.25,dup=0.15", 11u64),
            ("drop-reply=0.35,delay=3x,delay-p=0.5", 12),
            ("dup=0.3,drop-ack=0.3", 13),
        ] {
            let g = Arc::new(CholeskyGraph::new(CholeskyParams {
                tiles: 10,
                tile_size: 16,
                nodes: 3,
                dense_fraction: 0.5,
                seed: 9,
                all_dense: false,
            }));
            let total = g.total_tasks().unwrap();
            let r = Cluster::run(
                g,
                ClusterConfig::default()
                    .with_workers_per_node(2)
                    .with_migrate(MigrateConfig::default().with_poll_interval_us(20.0))
                    .with_seed(seed)
                    .with_record_polls(false)
                    .with_sched(backend)
                    .with_faults(spec.parse().unwrap()),
                Arc::new(NullExecutor),
            );
            assert_eq!(
                r.tasks_total_executed(),
                total,
                "faults={spec} sched={}",
                backend.label()
            );
        }
    }
}

/// A disabled plan must never perturb the DES, no matter what garbage
/// its probability fields hold: same makespan, same event counts, same
/// steal totals as the default reliable fabric, and none of the fault
/// machinery may fire. This is the regression wall for the "off ==
/// byte-identical to the pre-fault runtime" contract, swept over random
/// geometries and policies.
#[test]
fn prop_disabled_faults_never_perturb_the_des() {
    check(
        "faults-off-identical",
        Config {
            cases: 8,
            max_size: 12,
            seed: 0x0FF,
        },
        |rng, size| {
            let params = CholeskyParams {
                tiles: 4 + size as u32,
                tile_size: 16,
                nodes: 1 + rng.below(4) as u32,
                dense_fraction: rng.uniform(),
                seed: rng.next_u64(),
                all_dense: false,
            };
            let mc = random_migrate(rng);
            let seed = rng.next_u64();
            let workers = 1 + rng.below(4) as usize;
            let run = |faults: FaultPlan| {
                Simulator::new(
                    Arc::new(CholeskyGraph::new(params.clone())),
                    SimConfig::default()
                        .with_workers_per_node(workers)
                        .with_seed(seed)
                        .with_max_events(200_000_000)
                        .with_record_polls(false)
                        .with_pool_floor(2)
                        .with_faults(faults),
                    CostModel::default_calibrated(),
                    mc,
                    16,
                )
                .run()
            };
            let off = run(FaultPlan::default());
            let disabled = run(FaultPlan {
                enabled: false,
                drop_reply: 0.9,
                dup_request: 0.9,
                delay_factor: 8.0,
                crash_node: Some(1),
                crash_at_us: 50.0,
                crash_p: 0.9,
                ..Default::default()
            });
            prop_assert!(
                off.makespan_us == disabled.makespan_us,
                "makespan diverged: {} vs {}",
                off.makespan_us,
                disabled.makespan_us
            );
            prop_assert!(
                off.events == disabled.events && off.deliver_events == disabled.deliver_events,
                "event counts diverged: {}/{} vs {}/{}",
                off.events,
                off.deliver_events,
                disabled.events,
                disabled.deliver_events
            );
            let (a, b) = (off.total_steals(), disabled.total_steals());
            prop_assert!(
                a.requests_sent == b.requests_sent
                    && a.successful_steals == b.successful_steals
                    && a.tasks_migrated == b.tasks_migrated,
                "steal totals diverged"
            );
            prop_assert!(
                disabled.faults_dropped == 0
                    && disabled.faults_duplicated == 0
                    && disabled.steal_timeouts_total() == 0
                    && disabled.steal_retries_total() == 0
                    && disabled.ledger_reclaims_total() == 0
                    && disabled.dup_replies_suppressed_total() == 0
                    && disabled.recovery.nodes_crashed == 0
                    && disabled.recovery.nodes_suspected == 0
                    && disabled.recovery.tasks_recovered == 0,
                "fault machinery fired on a disabled plan"
            );
            Ok(())
        },
    );
}

/// CLI-surface drift guard for `--faults`: every spec the code can
/// print must parse back to the same plan, over random grids of
/// probabilities, delay factors and straggler windows (mirrors the
/// policy-label round-trip above).
#[test]
fn prop_faultplan_label_round_trips() {
    check(
        "faultplan-label-roundtrip",
        Config {
            cases: 150,
            max_size: 8,
            seed: 0xFA17,
        },
        |rng, _| {
            let plan = if rng.uniform() < 0.1 {
                FaultPlan::default() // "off"
            } else {
                let grid = |rng: &mut Rng| rng.below(95) as f64 / 100.0;
                let mut p = FaultPlan {
                    enabled: true,
                    drop_request: grid(rng),
                    drop_reply: grid(rng),
                    drop_ack: grid(rng),
                    dup_request: grid(rng),
                    dup_reply: grid(rng),
                    dup_ack: grid(rng),
                    ..Default::default()
                };
                if rng.uniform() < 0.3 {
                    // Uniform plans print the single-key spelling.
                    p.drop_reply = p.drop_request;
                    p.drop_ack = p.drop_request;
                }
                if rng.uniform() < 0.5 {
                    p.delay_factor = 1.0 + (1 + rng.below(20)) as f64 / 4.0;
                    p.delay_p = grid(rng);
                }
                if rng.uniform() < 0.5 {
                    p.slow_node = Some(rng.below(8) as u32);
                    if rng.uniform() < 0.5 {
                        p.slow_factor = (2 + rng.below(6)) as f64;
                    }
                    if rng.uniform() < 0.5 {
                        p.slow_from_us = (1 + rng.below(10_000)) as f64;
                    }
                    if rng.uniform() < 0.5 {
                        p.slow_until_us = p.slow_from_us + (1 + rng.below(50_000)) as f64;
                    }
                    p.stall = rng.uniform() < 0.5;
                }
                if rng.uniform() < 0.5 {
                    if rng.uniform() < 0.7 {
                        p.crash_node = Some(rng.below(8) as u32);
                    }
                    if rng.uniform() < 0.7 {
                        p.crash_at_us = (1 + rng.below(30_000)) as f64;
                    }
                    if rng.uniform() < 0.5 {
                        p.crash_p = (1 + rng.below(99)) as f64 / 100.0;
                    }
                }
                p
            };
            let label = plan.label();
            let parsed: FaultPlan = label
                .parse()
                .map_err(|e| format!("label '{label}' did not parse: {e}"))?;
            prop_assert!(
                parsed == plan,
                "label '{label}' round-tripped to {parsed:?}, wanted {plan:?}"
            );
            Ok(())
        },
    );
}

/// Crash-stop property: random Cholesky geometries losing a random
/// non-leader node at a random instant (sometimes composed with reply
/// drops) still execute every task exactly once among the survivors,
/// and the same schedule replayed with the same seed is bit-identical
/// — recovery is deterministic. A crash past the makespan is a no-op,
/// so the exactly-once claim holds unconditionally; across the sweep
/// at least one crash must actually fire and re-home work, or the
/// windows above are too tame.
#[test]
fn prop_crash_recovery_exactly_once_among_survivors() {
    let mut crashes = 0u64;
    let mut recovered = 0u64;
    check(
        "crash-exactly-once-among-survivors",
        Config {
            cases: 12,
            max_size: 10,
            seed: 0xC2A54,
        },
        |rng, size| {
            let nodes = 2 + rng.below(6) as u32;
            let graph = Arc::new(CholeskyGraph::new(CholeskyParams {
                tiles: 4 + size as u32,
                tile_size: 16,
                nodes,
                dense_fraction: 0.4 + rng.uniform() * 0.4,
                seed: rng.next_u64(),
                all_dense: false,
            }));
            let total = graph.total_tasks().unwrap();
            let plan = FaultPlan {
                enabled: true,
                crash_node: Some(1 + rng.below(nodes as u64 - 1) as u32),
                crash_at_us: 50.0 + rng.uniform() * 5_000.0,
                drop_reply: rng.uniform() * 0.1,
                ..Default::default()
            };
            let mut mc = random_migrate(rng);
            mc.enabled = true;
            mc.poll_interval_us = 15.0 + rng.uniform() * 40.0;
            let seed = rng.next_u64();
            let run = || {
                Simulator::new(
                    graph.clone(),
                    SimConfig::default()
                        .with_workers_per_node(2)
                        .with_seed(seed)
                        .with_max_events(200_000_000)
                        .with_record_polls(false)
                        .with_pool_floor(2)
                        .with_faults(plan),
                    CostModel::default_calibrated(),
                    mc,
                    16,
                )
                .run()
            };
            let r = run();
            prop_assert!(
                r.tasks_total_executed() == total,
                "plan '{}': executed {} of {total}",
                plan.label(),
                r.tasks_total_executed()
            );
            let replay = run();
            prop_assert!(
                replay.makespan_us == r.makespan_us
                    && replay.recovery.nodes_crashed == r.recovery.nodes_crashed
                    && replay.recovery.tasks_recovered == r.recovery.tasks_recovered
                    && replay.recovery.ring_repairs == r.recovery.ring_repairs,
                "same crash schedule, divergent replay"
            );
            crashes += r.recovery.nodes_crashed;
            recovered += r.recovery.tasks_recovered;
            Ok(())
        },
    );
    assert!(crashes > 0, "no crash ever fired across the sweep");
    assert!(recovered > 0, "no task was ever re-homed across the sweep");
}
