//! Backend-agreement property tests (in-tree `prop` driver), run over
//! the full backend matrix (central / sharded / workassist): every
//! other backend must be a semantic refinement of the central one —
//! identical select order where the semantics promise it (single-shard
//! sharded, workassist at any worker count), priority-then-FIFO per
//! shard in general, and identical task conservation under randomized
//! interleavings of insert / select / steal extraction.

use parsteal::dataflow::task::{TaskClass, TaskDesc};
use parsteal::prop_assert;
use parsteal::sched::{
    CentralQueue, SPILL_THRESHOLD, SchedBackend, Scheduler, ShardedQueue, TaskMeta,
};
use parsteal::util::prop::{check, Config};
use parsteal::util::rng::Rng;

fn t(i: u32) -> TaskDesc {
    TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0)
}

/// Backend matrix: one boxed instance of every scheduler backend, so a
/// property written once runs against all three.
fn matrix(workers: usize) -> Vec<Box<dyn Scheduler>> {
    let mut backends = Vec::new();
    for backend in SchedBackend::ALL {
        backends.push(backend.build(workers));
    }
    backends
}

/// With one shard and fewer tasks than the spill watermark the sharded
/// backend is order-identical to the central one: same priority-then-
/// FIFO select sequence.
#[test]
fn prop_single_shard_matches_central_order() {
    check(
        "single-shard-order",
        Config {
            cases: 64,
            max_size: SPILL_THRESHOLD,
            seed: 0x0DDE,
        },
        |rng, size| {
            let central = CentralQueue::new();
            let sharded = ShardedQueue::new(1);
            for i in 0..size as u32 {
                let prio = rng.next_u64() as i64 % 50;
                central.insert(t(i), prio);
                sharded.insert(t(i), prio);
            }
            for step in 0..size {
                let a = central.select();
                let b = sharded.select(0);
                prop_assert!(a == b, "diverged at step {step}: {a:?} vs {b:?}");
            }
            prop_assert!(sharded.select(0).is_none(), "sharded had extra tasks");
            Ok(())
        },
    );
}

/// The lock-free workassist backend is order-identical to the central
/// queue from single-threaded code at *any* worker count: every claim
/// walk targets the global (max priority, oldest insertion) entry, no
/// matter which worker asks — and it takes zero locks doing so.
#[test]
fn prop_workassist_matches_central_order() {
    check(
        "workassist-order",
        Config {
            cases: 64,
            max_size: 200,
            seed: 0x3AFE,
        },
        |rng, size| {
            let workers = 1 + rng.below(8) as usize;
            let central = CentralQueue::new();
            let assist = SchedBackend::Workassist.build(workers);
            for i in 0..size as u32 {
                let prio = rng.next_u64() as i64 % 50;
                central.insert(t(i), prio);
                assist.insert(t(i), prio);
            }
            for step in 0..size {
                let w = rng.below(workers as u64) as usize;
                let a = central.select();
                let b = assist.select(w);
                prop_assert!(a == b, "diverged at step {step}: {a:?} vs {b:?}");
            }
            prop_assert!(assist.select(0).is_none(), "workassist had extra tasks");
            let stats = assist.stats();
            prop_assert!(stats.lock_acquisitions == 0, "lock-free path took a lock");
            Ok(())
        },
    );
}

/// Per-shard select order is priority-then-FIFO: a worker draining its
/// own (round-robin-filled, unspilled) shard sees its tasks in exactly
/// the order the central queue would emit them.
#[test]
fn prop_per_shard_priority_then_fifo() {
    check(
        "per-shard-order",
        Config {
            cases: 48,
            max_size: 120,
            seed: 0x54A2D,
        },
        |rng, size| {
            let workers = 1 + rng.below(6) as usize;
            // Cap so no shard crosses the spill watermark.
            let n = size.min(workers * SPILL_THRESHOLD) as u32;
            let sharded = ShardedQueue::new(workers);
            let mut own: Vec<(i64, u32)> = Vec::new(); // (prio, insert index)
            for i in 0..n {
                let prio = rng.next_u64() as i64 % 10;
                sharded.insert(t(i), prio);
                if (i as usize) % workers == 0 {
                    own.push((prio, i));
                }
            }
            // Expected order for worker 0's shard: priority desc, then
            // insertion order asc.
            own.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
            for (k, (prio, i)) in own.iter().enumerate() {
                let got = sharded.select(0);
                prop_assert!(
                    got == Some(t(*i)),
                    "worker 0 step {k}: expected {} (prio {prio}), got {got:?}",
                    t(*i)
                );
            }
            // Remaining tasks (other shards) are still all reachable.
            let mut rest = 0;
            while sharded.select(0).is_some() {
                rest += 1;
            }
            prop_assert!(
                rest as u32 == n - own.len() as u32,
                "lost tasks: {rest} remained of {}",
                n - own.len() as u32
            );
            Ok(())
        },
    );
}

/// Randomized interleavings of insert / select / steal extraction keep
/// every backend in the matrix conserving tasks, with identical insert
/// and removal totals (select+steal split may differ — that is
/// scheduling policy, not conservation).
#[test]
fn prop_backends_conserve_under_interleaving() {
    #[derive(Clone, Copy)]
    enum Op {
        Insert(u32, i64),
        Select(usize),
        Steal(usize),
    }
    check(
        "backend-conservation",
        Config {
            cases: 60,
            max_size: 300,
            seed: 0xBAC0,
        },
        |rng, size| {
            let workers = 1 + rng.below(8) as usize;
            let mut ops = Vec::with_capacity(size);
            let mut next_id = 0u32;
            for _ in 0..size {
                ops.push(match rng.below(4) {
                    0 | 1 => {
                        let op = Op::Insert(next_id, rng.next_u64() as i64 % 1000);
                        next_id += 1;
                        op
                    }
                    2 => Op::Select(rng.below(workers as u64) as usize),
                    _ => Op::Steal(rng.below(5) as usize),
                });
            }
            let backends = matrix(workers);
            let mut removed_totals = Vec::new();
            for q in &backends {
                let mut inserted = std::collections::HashSet::new();
                let mut removed = std::collections::HashSet::new();
                for op in &ops {
                    match *op {
                        Op::Insert(id, prio) => {
                            q.insert(t(id), prio);
                            inserted.insert(t(id));
                        }
                        Op::Select(w) => {
                            if let Some(task) = q.select(w) {
                                prop_assert!(removed.insert(task), "duplicate select of {task}");
                            }
                        }
                        Op::Steal(max) => {
                            for task in q.extract_for_steal(max, &|task| task.i % 3 != 0) {
                                prop_assert!(task.i % 3 != 0, "filter violated");
                                prop_assert!(removed.insert(task), "duplicate steal of {task}");
                            }
                        }
                    }
                }
                while let Some(task) = q.select(0) {
                    prop_assert!(removed.insert(task), "duplicate drain of {task}");
                }
                prop_assert!(q.is_empty(), "{}: queue not empty after drain", q.name());
                prop_assert!(
                    inserted == removed,
                    "{}: conservation violated ({} in, {} out)",
                    q.name(),
                    inserted.len(),
                    removed.len()
                );
                let stats = q.stats();
                prop_assert!(
                    stats.selects + stats.steal_extracted == removed.len() as u64,
                    "{}: stats disagree with removal count",
                    q.name()
                );
                removed_totals.push(removed.len());
            }
            for pair in removed_totals.windows(2) {
                prop_assert!(
                    pair[0] == pair[1],
                    "backends disagree on total throughput: {removed_totals:?}"
                );
            }
            Ok(())
        },
    );
}

/// The incremental stealable-count/payload accounting must exactly
/// match the `count_matching` scan oracle (and a hand-tracked payload
/// multiset — sum *and* exact minimum) after every operation of a
/// random insert / select / extract_stealable / extract_for_steal
/// interleaving, on both backends. The minimum assertion is the
/// exact-multiset contract: after any removal sequence the reported
/// `min_stealable_payload_bytes` is the true minimum (not a stale
/// lower bound), with zero conservative resets.
#[test]
fn prop_incremental_accounting_matches_oracle() {
    use std::collections::BTreeMap;
    // Meta derived deterministically from the task id, so the oracle
    // filter can recognize stealable tasks without sharing state.
    fn meta_of(i: u32) -> TaskMeta {
        TaskMeta {
            stealable: i % 3 != 0,
            payload_bytes: 8 + (i as u64 % 11) * 16,
            class: TaskClass::Synthetic,
        }
    }
    let stealable_filter = |task: &TaskDesc| task.i % 3 != 0;

    #[derive(Clone, Copy)]
    enum Op {
        Insert(u32, i64),
        Select(usize),
        ExtractStealable(usize),
        ExtractFiltered(usize),
    }
    check(
        "incremental-accounting-oracle",
        Config {
            cases: 40,
            max_size: 200,
            seed: 0xACC7,
        },
        |rng, size| {
            let workers = 1 + rng.below(6) as usize;
            let mut ops = Vec::with_capacity(size);
            let mut next_id = 0u32;
            for _ in 0..size {
                ops.push(match rng.below(5) {
                    0 | 1 => {
                        let op = Op::Insert(next_id, rng.next_u64() as i64 % 100);
                        next_id += 1;
                        op
                    }
                    2 => Op::Select(rng.below(workers as u64) as usize),
                    3 => Op::ExtractStealable(rng.below(6) as usize),
                    _ => Op::ExtractFiltered(rng.below(6) as usize),
                });
            }
            for backend in SchedBackend::ALL {
                let q = backend.build(workers);
                // Hand-tracked multiset of queued stealable payloads.
                let mut payloads: BTreeMap<u64, usize> = BTreeMap::new();
                let remove = |task: TaskDesc, payloads: &mut BTreeMap<u64, usize>| {
                    if stealable_filter(&task) {
                        let p = meta_of(task.i).payload_bytes;
                        match payloads.get_mut(&p) {
                            Some(n) if *n > 1 => *n -= 1,
                            _ => {
                                payloads.remove(&p);
                            }
                        }
                    }
                };
                for op in &ops {
                    match *op {
                        Op::Insert(id, prio) => {
                            q.insert_meta(t(id), prio, meta_of(id));
                            if id % 3 != 0 {
                                *payloads.entry(meta_of(id).payload_bytes).or_insert(0) += 1;
                            }
                        }
                        Op::Select(w) => {
                            if let Some(task) = q.select(w) {
                                remove(task, &mut payloads);
                            }
                        }
                        Op::ExtractStealable(max) => {
                            for task in q.extract_stealable(max) {
                                prop_assert!(
                                    stealable_filter(&task),
                                    "{}: non-stealable task {task} extracted",
                                    q.name()
                                );
                                remove(task, &mut payloads);
                            }
                        }
                        Op::ExtractFiltered(max) => {
                            // Oracle extraction over a *different* filter:
                            // accounting must stay exact even when the
                            // scan path removes stealable tasks.
                            for task in q.extract_for_steal(max, &|task| task.i % 2 == 0) {
                                remove(task, &mut payloads);
                            }
                        }
                    }
                    let oracle = q.count_matching(&stealable_filter);
                    prop_assert!(
                        q.stealable_count() == oracle,
                        "{}: stealable_count {} != oracle {oracle}",
                        q.name(),
                        q.stealable_count()
                    );
                    let tracked_sum: u64 = payloads.iter().map(|(p, n)| p * *n as u64).sum();
                    prop_assert!(
                        q.stealable_payload_bytes() == tracked_sum,
                        "{}: payload {} != tracked {tracked_sum}",
                        q.name(),
                        q.stealable_payload_bytes()
                    );
                    let tracked_min = payloads.keys().next().copied().unwrap_or(u64::MAX);
                    prop_assert!(
                        q.min_stealable_payload_bytes() == tracked_min,
                        "{}: min payload {} != exact multiset min {tracked_min}",
                        q.name(),
                        q.min_stealable_payload_bytes()
                    );
                }
                prop_assert!(
                    q.stats().min_payload_resets == 0,
                    "{}: exact multiset must never reset conservatively",
                    q.name()
                );
            }
            Ok(())
        },
    );
}

/// `insert_batch_meta` is observationally equivalent to the same
/// sequence of `insert_meta` calls on the central and workassist
/// backends (identical select order and accounting — for workassist
/// that means one published block behaves exactly like a chain of
/// single-entry blocks), and preserves the accounting + conservation
/// contract on the sharded one (placement may differ — a batch lands
/// in one shard — but nothing is lost and the incremental census stays
/// exact).
#[test]
fn prop_batch_insert_matches_sequential_insert() {
    fn meta_of(i: u32) -> TaskMeta {
        TaskMeta {
            stealable: i % 3 != 0,
            payload_bytes: 8 + (i as u64 % 7) * 32,
            class: TaskClass::Synthetic,
        }
    }
    check(
        "batch-insert-equivalence",
        Config {
            cases: 48,
            max_size: 160,
            seed: 0xBA7C,
        },
        |rng, size| {
            let workers = 1 + rng.below(6) as usize;
            // Pre-fill both queues identically, then apply one batch vs
            // the same triples one at a time.
            let pre: Vec<(u32, i64)> = (0..rng.below(20) as u32)
                .map(|i| (1000 + i, rng.next_u64() as i64 % 50))
                .collect();
            let batch: Vec<(TaskDesc, i64, TaskMeta)> = (0..size as u32)
                .map(|i| (t(i), rng.next_u64() as i64 % 50, meta_of(i)))
                .collect();

            let a = CentralQueue::new();
            let b = CentralQueue::new();
            for &(i, prio) in &pre {
                a.insert_meta(t(i), prio, meta_of(i));
                b.insert_meta(t(i), prio, meta_of(i));
            }
            a.insert_batch_meta(&batch);
            for &(task, prio, meta) in &batch {
                b.insert_meta(task, prio, meta);
            }
            prop_assert!(
                a.stealable_count() == b.stealable_count()
                    && a.stealable_payload_bytes() == b.stealable_payload_bytes(),
                "central: accounting diverged"
            );
            for step in 0..a.len() {
                let (x, y) = (a.select(), b.select());
                prop_assert!(x == y, "central: select diverged at {step}: {x:?} vs {y:?}");
            }

            // Sharded: conservation + exact census after a batch.
            let q = ShardedQueue::new(workers);
            for &(i, prio) in &pre {
                q.insert_meta(t(i), prio, meta_of(i));
            }
            q.insert_batch_meta(&batch);
            let pre_stealable = pre.iter().filter(|(i, _)| meta_of(*i).stealable).count();
            let want_stealable =
                pre_stealable + batch.iter().filter(|(_, _, m)| m.stealable).count();
            prop_assert!(
                q.stealable_count() == want_stealable,
                "sharded: stealable {} != {want_stealable}",
                q.stealable_count()
            );
            prop_assert!(
                q.len() == pre.len() + batch.len(),
                "sharded: len {} != {}",
                q.len(),
                pre.len() + batch.len()
            );
            let mut drained = 0;
            for w in 0..workers {
                while q.select(w).is_some() {
                    drained += 1;
                }
            }
            prop_assert!(
                drained == pre.len() + batch.len(),
                "sharded: conservation violated ({drained})"
            );

            // Workassist: one published block must be observationally
            // identical to the same sequence of single-entry blocks.
            let wa_batch = SchedBackend::Workassist.build(workers);
            let wa_seq = SchedBackend::Workassist.build(workers);
            for &(i, prio) in &pre {
                wa_batch.insert_meta(t(i), prio, meta_of(i));
                wa_seq.insert_meta(t(i), prio, meta_of(i));
            }
            wa_batch.insert_batch_meta(&batch);
            for &(task, prio, meta) in &batch {
                wa_seq.insert_meta(task, prio, meta);
            }
            prop_assert!(
                wa_batch.stealable_count() == wa_seq.stealable_count(),
                "workassist: stealable count diverged"
            );
            prop_assert!(
                wa_batch.stealable_payload_bytes() == wa_seq.stealable_payload_bytes(),
                "workassist: payload sum diverged"
            );
            prop_assert!(
                wa_batch.min_stealable_payload_bytes() == wa_seq.min_stealable_payload_bytes(),
                "workassist: payload min diverged"
            );
            for step in 0..wa_batch.len() {
                let (x, y) = (wa_batch.select(0), wa_seq.select(0));
                prop_assert!(x == y, "workassist: select diverged at {step}: {x:?} vs {y:?}");
            }
            Ok(())
        },
    );
}

/// The per-class queued counts must exactly match the `count_matching`
/// oracle for every class after every operation of a random insert /
/// select / extract / batch-insert interleaving, on both backends —
/// the accounting the `--exec-per-class` waiting-time estimator trusts.
#[test]
fn prop_class_counts_match_oracle() {
    fn class_of(i: u32) -> TaskClass {
        TaskClass::ALL[(i as usize) % TaskClass::COUNT]
    }
    fn ct(i: u32) -> TaskDesc {
        TaskDesc::indexed(class_of(i), i, 0, 0)
    }
    fn meta_of(i: u32) -> TaskMeta {
        TaskMeta {
            stealable: i % 3 != 0,
            payload_bytes: 8 + (i as u64 % 5) * 16,
            class: class_of(i),
        }
    }

    #[derive(Clone, Copy)]
    enum Op {
        Insert(u32, i64),
        InsertBatch(u32, usize),
        Select(usize),
        ExtractStealable(usize),
        ExtractFiltered(usize),
    }
    check(
        "class-counts-oracle",
        Config {
            cases: 40,
            max_size: 160,
            seed: 0xC1A55,
        },
        |rng, size| {
            let workers = 1 + rng.below(6) as usize;
            let mut ops = Vec::with_capacity(size);
            let mut next_id = 0u32;
            for _ in 0..size {
                ops.push(match rng.below(6) {
                    0 | 1 => {
                        let op = Op::Insert(next_id, rng.next_u64() as i64 % 100);
                        next_id += 1;
                        op
                    }
                    2 => {
                        let n = 1 + rng.below(5) as u32;
                        let op = Op::InsertBatch(next_id, n as usize);
                        next_id += n;
                        op
                    }
                    3 => Op::Select(rng.below(workers as u64) as usize),
                    4 => Op::ExtractStealable(rng.below(6) as usize),
                    _ => Op::ExtractFiltered(rng.below(6) as usize),
                });
            }
            for backend in SchedBackend::ALL {
                let q = backend.build(workers);
                for op in &ops {
                    match *op {
                        Op::Insert(id, prio) => q.insert_meta(ct(id), prio, meta_of(id)),
                        Op::InsertBatch(first, n) => {
                            let batch: Vec<(TaskDesc, i64, TaskMeta)> = (first..first + n as u32)
                                .map(|id| (ct(id), id as i64 % 50, meta_of(id)))
                                .collect();
                            q.insert_batch_meta(&batch);
                        }
                        Op::Select(w) => {
                            let _ = q.select(w);
                        }
                        Op::ExtractStealable(max) => {
                            let _ = q.extract_stealable(max);
                        }
                        Op::ExtractFiltered(max) => {
                            let _ = q.extract_for_steal(max, &|task| task.i % 2 == 0);
                        }
                    }
                    let counts = q.class_counts();
                    for class in TaskClass::ALL {
                        let oracle = q.count_matching(&|task| task.class == class);
                        prop_assert!(
                            counts[class.idx()] == oracle,
                            "{}: class {class:?} count {} != oracle {oracle}",
                            q.name(),
                            counts[class.idx()]
                        );
                    }
                    prop_assert!(
                        counts.iter().sum::<usize>() == q.len(),
                        "{}: class counts must sum to the queue length",
                        q.name()
                    );
                }
            }
            Ok(())
        },
    );
}

/// Diagnostics agree: after identical inserts, every backend in the
/// matrix reports the same length, max priority and filtered count.
#[test]
fn prop_len_and_max_priority_agree() {
    check(
        "len-maxprio-agree",
        Config {
            cases: 40,
            max_size: 200,
            seed: 0x11AB,
        },
        |rng, size| {
            let workers = 1 + rng.below(8) as usize;
            let backends = matrix(workers);
            for i in 0..size as u32 {
                let prio = rng.next_u64() as i64 % 100 - 50;
                for q in &backends {
                    q.insert(t(i), prio);
                }
            }
            let evens = &|task: &TaskDesc| task.i % 2 == 0;
            for q in &backends[1..] {
                prop_assert!(
                    q.len() == backends[0].len(),
                    "{}: len {} vs {}",
                    q.name(),
                    q.len(),
                    backends[0].len()
                );
                prop_assert!(
                    q.max_priority() == backends[0].max_priority(),
                    "{}: max_priority {:?} vs {:?}",
                    q.name(),
                    q.max_priority(),
                    backends[0].max_priority()
                );
                prop_assert!(
                    q.count_matching(evens) == backends[0].count_matching(evens),
                    "{}: count_matching disagrees",
                    q.name()
                );
            }
            Ok(())
        },
    );
}
