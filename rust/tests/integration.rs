//! Cross-module integration tests: DES ↔ real runtime agreement, steal
//! protocol end to end, figure harness smoke, config plumbing.

use std::sync::Arc;

use parsteal::comm::LinkModel;
use parsteal::dataflow::ttg::TaskGraph;
use parsteal::faults::FaultPlan;
use parsteal::migrate::{MigrateConfig, ThiefPolicy, VictimPolicy, VictimSelect};
use parsteal::node::{Cluster, ClusterConfig, NullExecutor, SpinExecutor};
use parsteal::sched::SchedBackend;
use parsteal::sim::{CostModel, SimConfig, Simulator};
use parsteal::topology::{StealDomains, Topology};
use parsteal::workloads::{CholeskyGraph, CholeskyParams, UtsGraph, UtsParams};

fn chol(tiles: u32, nodes: u32) -> Arc<CholeskyGraph> {
    Arc::new(CholeskyGraph::new(CholeskyParams {
        tiles,
        tile_size: 16,
        nodes,
        dense_fraction: 0.5,
        seed: 9,
        all_dense: false,
    }))
}

/// The same graph executed by the DES and the threaded runtime must
/// agree on the total task count and (with no stealing) on the exact
/// per-node distribution — both follow the same static owner mapping.
#[test]
fn sim_and_real_agree_on_static_distribution() {
    let g = chol(10, 3);
    let sim = Simulator::new(
        g.clone(),
        SimConfig::default()
            .with_workers_per_node(2)
            .with_seed(4)
            .with_record_polls(false),
        CostModel::default_calibrated(),
        MigrateConfig::disabled(),
        16,
    )
    .run();
    let real = Cluster::run(
        g.clone(),
        ClusterConfig::default()
            .with_workers_per_node(2)
            .with_migrate(MigrateConfig::disabled())
            .with_seed(4)
            .with_record_polls(false),
        Arc::new(NullExecutor),
    );
    assert_eq!(sim.tasks_total_executed(), real.tasks_total_executed());
    let sim_dist: Vec<u64> = sim.nodes.iter().map(|n| n.tasks_executed).collect();
    let real_dist: Vec<u64> = real.nodes.iter().map(|n| n.tasks_executed).collect();
    assert_eq!(sim_dist, real_dist, "static mapping must be identical");
}

/// With stealing enabled in the real runtime, every task still executes
/// exactly once — across every policy combination.
#[test]
fn real_runtime_steals_preserve_exactly_once() {
    for victim in [VictimPolicy::Half, VictimPolicy::Chunk(4), VictimPolicy::Single] {
        for thief in [ThiefPolicy::ReadyOnly, ThiefPolicy::ReadySuccessors] {
            let g = chol(8, 3);
            let total = g.total_tasks().unwrap();
            let cost = CostModel::default_calibrated();
            let g2 = g.clone();
            let r = Cluster::run(
                g.clone(),
                ClusterConfig::default()
                    .with_workers_per_node(2)
                    .with_migrate(
                        MigrateConfig::default()
                            .with_thief(thief)
                            .with_victim(victim)
                            .with_poll_interval_us(20.0),
                    )
                    .with_seed(5)
                    .with_record_polls(false),
                Arc::new(SpinExecutor::new(cost, 16, move |t| g2.work_units(t)).with_time_scale(0.2)),
            );
            assert_eq!(
                r.tasks_total_executed(),
                total,
                "victim={victim:?} thief={thief:?}"
            );
        }
    }
}

/// UTS in the real runtime: dynamic task creation + stealing + Safra
/// termination on a tree nobody knows the size of in advance.
#[test]
fn real_runtime_uts_dynamic_termination() {
    let g = Arc::new(UtsGraph::new(UtsParams {
        b0: 20,
        m: 3,
        q: 0.3,
        g: 5_000.0,
        seed: 2,
        nodes: 3,
        max_depth: 14,
    }));
    let size = g.tree_size(10_000_000);
    let g2 = g.clone();
    let r = Cluster::run(
        g.clone(),
        ClusterConfig::default()
            .with_workers_per_node(2)
            .with_migrate(MigrateConfig::default().with_poll_interval_us(20.0))
            .with_seed(6)
            .with_record_polls(false),
        Arc::new(
            SpinExecutor::new(CostModel::default_calibrated(), 0, move |t| g2.work_units(t))
                .with_time_scale(0.01),
        ),
    );
    assert_eq!(r.tasks_total_executed(), size);
}

/// Backend sweep: the sharded scheduler must preserve the sim ↔ real
/// agreement the central one gives — same totals in both runtimes, and
/// with stealing disabled the same static distribution.
#[test]
fn sharded_backend_sim_and_real_agree() {
    let g = chol(10, 3);
    let total = g.total_tasks().unwrap();
    let sim = Simulator::new(
        g.clone(),
        SimConfig::default()
            .with_workers_per_node(2)
            .with_seed(4)
            .with_record_polls(false)
            .with_sched(SchedBackend::Sharded),
        CostModel::default_calibrated(),
        MigrateConfig::disabled(),
        16,
    )
    .run();
    let real = Cluster::run(
        g.clone(),
        ClusterConfig::default()
            .with_workers_per_node(2)
            .with_migrate(MigrateConfig::disabled())
            .with_seed(4)
            .with_record_polls(false)
            .with_sched(SchedBackend::Sharded),
        Arc::new(NullExecutor),
    );
    assert_eq!(sim.tasks_total_executed(), total);
    assert_eq!(real.tasks_total_executed(), total);
    let sim_dist: Vec<u64> = sim.nodes.iter().map(|n| n.tasks_executed).collect();
    let real_dist: Vec<u64> = real.nodes.iter().map(|n| n.tasks_executed).collect();
    assert_eq!(sim_dist, real_dist, "static mapping must be identical");
}

/// Backend sweep, lock-free arm: the workassist scheduler must preserve
/// the same sim ↔ real agreement — same totals in both runtimes, the
/// same static distribution with stealing disabled — and both runs must
/// finish with zero mutex acquisitions on every node queue: the whole
/// execution rode the claim CAS, never a lock.
#[test]
fn workassist_backend_sim_and_real_agree() {
    let g = chol(10, 3);
    let total = g.total_tasks().unwrap();
    let sim = Simulator::new(
        g.clone(),
        SimConfig::default()
            .with_workers_per_node(2)
            .with_seed(4)
            .with_record_polls(false)
            .with_sched(SchedBackend::Workassist),
        CostModel::default_calibrated(),
        MigrateConfig::disabled(),
        16,
    )
    .run();
    let real = Cluster::run(
        g.clone(),
        ClusterConfig::default()
            .with_workers_per_node(2)
            .with_migrate(MigrateConfig::disabled())
            .with_seed(4)
            .with_record_polls(false)
            .with_sched(SchedBackend::Workassist),
        Arc::new(NullExecutor),
    );
    assert_eq!(sim.tasks_total_executed(), total);
    assert_eq!(real.tasks_total_executed(), total);
    let sim_dist: Vec<u64> = sim.nodes.iter().map(|n| n.tasks_executed).collect();
    let real_dist: Vec<u64> = real.nodes.iter().map(|n| n.tasks_executed).collect();
    assert_eq!(sim_dist, real_dist, "static mapping must be identical");
    // The end-to-end lock-freedom assert: a full run on the lock-free
    // backend never takes a queue mutex, in either runtime, on any node.
    for (report, kind) in [(&sim, "sim"), (&real, "real")] {
        for (ix, node) in report.nodes.iter().enumerate() {
            assert_eq!(
                node.sched.lock_acquisitions, 0,
                "{kind} node {ix}: workassist took a lock"
            );
        }
    }
}

/// Activation batching must cut the DES wire-event count measurably on
/// the 8-node Cholesky e2e while executing exactly the same tasks on
/// exactly the same nodes (stealing disabled, so the static owner map
/// pins the distribution and the comparison is exact).
#[test]
fn batched_activations_cut_deliver_events() {
    let run = |batch: bool| {
        let g = Arc::new(CholeskyGraph::new(CholeskyParams {
            tiles: 16,
            tile_size: 16,
            nodes: 8,
            dense_fraction: 1.0,
            seed: 9,
            all_dense: true,
        }));
        Simulator::new(
            g,
            SimConfig::default()
                .with_workers_per_node(4)
                .with_seed(4)
                .with_record_polls(false)
                .with_batch_activations(batch),
            CostModel::default_calibrated(),
            MigrateConfig::disabled(),
            16,
        )
        .run()
    };
    let batched = run(true);
    let unbatched = run(false);
    assert_eq!(
        batched.tasks_total_executed(),
        unbatched.tasks_total_executed()
    );
    let bd: Vec<u64> = batched.nodes.iter().map(|n| n.tasks_executed).collect();
    let ud: Vec<u64> = unbatched.nodes.iter().map(|n| n.tasks_executed).collect();
    assert_eq!(bd, ud, "identical per-node tasks_executed");
    assert!(batched.deliver_events > 0, "remote edges exist");
    let ratio = batched.deliver_events as f64 / unbatched.deliver_events as f64;
    assert!(
        ratio <= 0.85,
        "batching saved too little: {} vs {} Deliver events (ratio {ratio:.3})",
        batched.deliver_events,
        unbatched.deliver_events
    );
}

/// Batched and unbatched activation protocols agree between the DES and
/// the threaded runtime: same totals, same static per-node distribution.
#[test]
fn batched_and_unbatched_agree_des_vs_threaded() {
    for batch in [false, true] {
        let g = chol(10, 3);
        let total = g.total_tasks().unwrap();
        let sim = Simulator::new(
            g.clone(),
            SimConfig::default()
                .with_workers_per_node(2)
                .with_seed(8)
                .with_record_polls(false)
                .with_batch_activations(batch),
            CostModel::default_calibrated(),
            MigrateConfig::disabled(),
            16,
        )
        .run();
        let real = Cluster::run(
            g.clone(),
            ClusterConfig::default()
                .with_workers_per_node(2)
                .with_migrate(MigrateConfig::disabled())
                .with_seed(8)
                .with_record_polls(false)
                .with_batch_activations(batch),
            Arc::new(NullExecutor),
        );
        assert_eq!(sim.tasks_total_executed(), total, "batch={batch}");
        assert_eq!(real.tasks_total_executed(), total, "batch={batch}");
        let sim_dist: Vec<u64> = sim.nodes.iter().map(|n| n.tasks_executed).collect();
        let real_dist: Vec<u64> = real.nodes.iter().map(|n| n.tasks_executed).collect();
        assert_eq!(sim_dist, real_dist, "batch={batch}: same distribution");
    }
}

/// `--exec-per-class` (± `--share-estimates`) equivalence between the
/// runtimes, swept over both values of the sharing flag so the
/// paper-faithful per-node configuration keeps its own cross-runtime
/// coverage: both execute every task exactly once; with sharing on in
/// the steal-friendly regime both merge digests (one per successful
/// steal, with cold-class adoptions on the thieves) and with it off
/// neither merges any; in the denial-certain regime (overhead dwarfs
/// any waiting time) they agree on the steal outcome totals — zero
/// grants, zero migrated tasks, zero digests — while the deterministic
/// DES also observes the denials themselves.
#[test]
fn share_estimates_des_and_threaded_agree() {
    let mk_migrate = |overhead: f64, share: bool| {
        MigrateConfig::default()
            .with_poll_interval_us(20.0)
            .with_migrate_overhead_us(overhead)
            .with_exec_per_class(true)
            .with_share_estimates(share)
    };
    // All work starts on node 0, so thieves are permanently starving
    // and the victim always has a stealable queue — every request in
    // the denial-certain regime becomes a waiting-time denial in both
    // runtimes (the same shape the denial-heavy feedback tests use).
    let mk_uts = || {
        Arc::new(UtsGraph::new(UtsParams {
            b0: 24,
            m: 4,
            q: 0.3,
            g: 30_000.0,
            seed: 5,
            nodes: 3,
            max_depth: 18,
        }))
    };
    for share in [false, true] {
        for overhead in [150.0, 1e9] {
            let g = mk_uts();
            let size = g.tree_size(10_000_000);
            let sim = Simulator::new(
                g,
                SimConfig::default()
                    .with_workers_per_node(2)
                    .with_seed(4)
                    .with_record_polls(false),
                CostModel::default_calibrated(),
                mk_migrate(overhead, share),
                0,
            )
            .run();
            let g = mk_uts();
            // 30 µs/task, as in the denial-heavy feedback e2e: long
            // enough that thieves poll many times while node 0 still
            // has a queue.
            let ex = SpinExecutor::new(CostModel::default_calibrated(), 0, |_| 30_000.0);
            let real = Cluster::run(
                g,
                ClusterConfig::default()
                    .with_workers_per_node(2)
                    .with_migrate(mk_migrate(overhead, share))
                    .with_seed(4)
                    .with_record_polls(false),
                Arc::new(ex),
            );
            let tag = format!("share={share} overhead={overhead}");
            assert_eq!(sim.tasks_total_executed(), size, "{tag}");
            assert_eq!(real.tasks_total_executed(), size, "{tag}");
            let (s, r) = (sim.total_steals(), real.total_steals());
            if overhead >= 1e9 {
                assert_eq!(s.successful_steals, 0, "{tag}: DES gate denies all");
                assert_eq!(r.successful_steals, 0, "{tag}: threaded gate denies all");
                assert_eq!(s.tasks_migrated + r.tasks_migrated, 0, "{tag}");
                assert!(s.waiting_time_denials > 0, "{tag}: DES observed denials");
                assert!(r.waiting_time_denials > 0, "{tag}: threaded observed denials");
            } else {
                assert!(s.successful_steals > 0, "{tag}: DES steals must land");
                assert!(r.successful_steals > 0, "{tag}: threaded steals must land");
            }
            if share && overhead < 1e9 {
                // Steal-friendly sharing: both runtimes merge exactly
                // one digest per successful steal, and the UTS thieves
                // start cold, so the class entry arrives by adoption.
                assert_eq!(
                    sim.digest_merges_total(),
                    s.successful_steals,
                    "{tag}: DES one digest per successful steal"
                );
                assert_eq!(
                    real.digest_merges_total(),
                    r.successful_steals,
                    "{tag}: threaded one digest per successful steal"
                );
                assert!(sim.digest_class_adoptions_total() > 0, "{tag}: DES adoptions");
                assert!(
                    real.digest_class_adoptions_total() > 0,
                    "{tag}: threaded adoptions"
                );
            } else {
                // Flag off (or nothing granted): no digests anywhere.
                assert_eq!(sim.digest_merges_total(), 0, "{tag}: DES no digests");
                assert_eq!(real.digest_merges_total(), 0, "{tag}: threaded no digests");
            }
        }
    }
}

/// `--victim-select targeted` equivalence between the runtimes, swept
/// over both selection modes: every task still executes exactly once,
/// steals land in both runtimes, and each runtime's per-victim outcome
/// tables are internally consistent (grants mirror successful steals,
/// no node ever records an outcome against itself). The two runtimes
/// differ in timing, so the sweep checks structural invariants, not
/// equal victim sequences.
#[test]
fn targeted_victim_selection_des_and_threaded_agree() {
    let mk_uts = || {
        Arc::new(UtsGraph::new(UtsParams {
            b0: 24,
            m: 4,
            q: 0.3,
            g: 30_000.0,
            seed: 5,
            nodes: 3,
            max_depth: 18,
        }))
    };
    for select in [VictimSelect::Uniform, VictimSelect::Targeted] {
        let mc = MigrateConfig::default()
            .with_poll_interval_us(20.0)
            .with_share_estimates(true)
            .with_victim_select(select);
        let g = mk_uts();
        let size = g.tree_size(10_000_000);
        let sim = Simulator::new(
            g,
            SimConfig::default()
                .with_workers_per_node(2)
                .with_seed(4)
                .with_record_polls(false),
            CostModel::default_calibrated(),
            mc,
            0,
        )
        .run();
        let real = Cluster::run(
            mk_uts(),
            ClusterConfig::default()
                .with_workers_per_node(2)
                .with_migrate(mc)
                .with_seed(4)
                .with_record_polls(false),
            Arc::new(SpinExecutor::new(
                CostModel::default_calibrated(),
                0,
                |_| 30_000.0,
            )),
        );
        let tag = format!("select={select:?}");
        assert_eq!(sim.tasks_total_executed(), size, "{tag}: DES");
        assert_eq!(real.tasks_total_executed(), size, "{tag}: threaded");
        assert!(sim.total_steals().successful_steals > 0, "{tag}: DES steals");
        assert!(
            real.total_steals().successful_steals > 0,
            "{tag}: threaded steals"
        );
        for report in [&sim, &real] {
            for (ix, n) in report.nodes.iter().enumerate() {
                let grants: u64 = n.victim_grants.iter().sum();
                assert_eq!(
                    grants, n.steal.successful_steals,
                    "{tag} node {ix}: grants mirror successful steals"
                );
                assert_eq!(
                    n.victim_grants[ix] + n.victim_wt_denials[ix] + n.victim_empties[ix],
                    0,
                    "{tag} node {ix}: never an outcome against itself"
                );
            }
        }
    }
}

/// Hierarchical steal domains on a two-tier topology, DES vs threaded:
/// both runtimes honour the same `Topology` + `StealDomains` knobs from
/// the same config surface, both execute every UTS task exactly once
/// with steals landing, and both keep their per-tier steal ledgers
/// internally consistent — the tier counters sum to the thief-side
/// requests sent, and under hierarchical domains the near (socket)
/// tier is actually exercised before escalation in both runtimes. The
/// runtimes differ in timing, so the threaded arm checks structure,
/// not counts equal to the DES.
#[test]
fn hierarchical_domains_des_and_threaded_agree() {
    let topo = Topology::two_tier(
        2,
        LinkModel {
            latency_us: 1.0,
            bw_bytes_per_us: 20_000.0,
        },
        LinkModel {
            latency_us: 40.0,
            bw_bytes_per_us: 1_000.0,
        },
    );
    let mk_uts = || {
        Arc::new(UtsGraph::new(UtsParams {
            b0: 24,
            m: 4,
            q: 0.3,
            g: 30_000.0,
            seed: 5,
            nodes: 4,
            max_depth: 18,
        }))
    };
    let mc = MigrateConfig::default().with_poll_interval_us(20.0);
    for domains in [StealDomains::Flat, StealDomains::Hierarchical] {
        let g = mk_uts();
        let size = g.tree_size(10_000_000);
        let sim = Simulator::new(
            g,
            SimConfig::default()
                .with_workers_per_node(2)
                .with_seed(4)
                .with_record_polls(false)
                .with_topology(topo)
                .with_steal_domains(domains),
            CostModel::default_calibrated(),
            mc,
            0,
        )
        .run();
        let real = Cluster::run(
            mk_uts(),
            ClusterConfig::default()
                .with_workers_per_node(2)
                .with_migrate(mc)
                .with_seed(4)
                .with_record_polls(false)
                .with_topology(topo)
                .with_steal_domains(domains),
            Arc::new(SpinExecutor::new(
                CostModel::default_calibrated(),
                0,
                |_| 30_000.0,
            )),
        );
        let tag = format!("domains={}", domains.label());
        assert_eq!(sim.tasks_total_executed(), size, "{tag}: DES exactly once");
        assert_eq!(
            real.tasks_total_executed(),
            size,
            "{tag}: threaded exactly once"
        );
        assert!(sim.total_steals().successful_steals > 0, "{tag}: DES steals");
        assert!(
            real.total_steals().successful_steals > 0,
            "{tag}: threaded steals"
        );
        for (report, kind) in [(&sim, "DES"), (&real, "threaded")] {
            let tiers = report.tier_steal_totals();
            let tier_req_sum: u64 = tiers.iter().map(|(req, _, _)| req).sum();
            assert_eq!(
                tier_req_sum,
                report.total_steals().requests_sent,
                "{tag} {kind}: tier ledger covers every request"
            );
            if domains == StealDomains::Hierarchical {
                assert!(
                    tiers[0].0 > 0,
                    "{tag} {kind}: hierarchical thieves try their socket first"
                );
            }
        }
    }
}

/// Crash-stop agreement between the runtimes on the acceptance
/// scenario: an 8-node Cholesky losing one of several swept nodes a
/// third of the way through its (baseline-measured) makespan. Both
/// runtimes must still execute the full task set exactly once among
/// the survivors — the surviving-task totals agree by construction —
/// each must confirm exactly one crash and one ring splice, and the
/// DES must replay the same crash schedule bit-identically.
#[test]
fn crash_recovery_des_and_threaded_agree() {
    let g = chol(10, 8);
    let total = g.total_tasks().unwrap();
    let mc = MigrateConfig::default().with_poll_interval_us(30.0);
    let sim_run = |faults: FaultPlan| {
        Simulator::new(
            g.clone(),
            SimConfig::default()
                .with_workers_per_node(2)
                .with_seed(4)
                .with_record_polls(false)
                .with_faults(faults),
            CostModel::default_calibrated(),
            mc,
            16,
        )
        .run()
    };
    let g2 = g.clone();
    let ex = Arc::new(
        SpinExecutor::new(CostModel::default_calibrated(), 16, move |t| g2.work_units(t))
            .with_time_scale(0.2),
    );
    let real_run = |faults: FaultPlan| {
        Cluster::run(
            g.clone(),
            ClusterConfig::default()
                .with_workers_per_node(2)
                .with_migrate(mc)
                .with_seed(4)
                .with_record_polls(false)
                .with_faults(faults),
            ex.clone(),
        )
    };
    // Fault-free baselines pin the crash instant to mid-run on each
    // runtime's own clock (virtual for the DES, wall for the cluster).
    let base_sim = sim_run(FaultPlan::default());
    let base_real = real_run(FaultPlan::default());
    assert_eq!(base_sim.tasks_total_executed(), total);
    assert_eq!(base_real.tasks_total_executed(), total);
    let sim_at = (base_sim.makespan_us / 3.0).max(50.0);
    let real_at = (base_real.makespan_us / 3.0).max(500.0);
    for dead in [1u32, 4, 7] {
        let plan = |at: f64| -> FaultPlan {
            format!("crash-node={dead},crash-at-us={at:.0}").parse().unwrap()
        };
        let sim = sim_run(plan(sim_at));
        assert_eq!(
            sim.tasks_total_executed(),
            total,
            "dead={dead}: DES exactly once among survivors"
        );
        assert_eq!(sim.recovery.nodes_crashed, 1, "dead={dead}: DES crash fired");
        assert_eq!(sim.recovery.ring_repairs, 1, "dead={dead}: DES ring splice");
        let replay = sim_run(plan(sim_at));
        assert_eq!(
            sim.makespan_us, replay.makespan_us,
            "dead={dead}: DES crash replay must be bit-identical"
        );
        assert_eq!(
            sim.recovery.tasks_recovered, replay.recovery.tasks_recovered,
            "dead={dead}: DES recovery is deterministic"
        );
        let real = real_run(plan(real_at));
        assert_eq!(
            real.tasks_total_executed(),
            total,
            "dead={dead}: threaded exactly once among survivors"
        );
        assert_eq!(real.recovery.nodes_crashed, 1, "dead={dead}: threaded crash fired");
        assert_eq!(real.recovery.ring_repairs, 1, "dead={dead}: threaded ring splice");
    }
}

/// The network's latency model must delay but never lose messages even
/// under hundreds of concurrent senders.
#[test]
fn network_stress_no_loss() {
    use parsteal::comm::{Msg, Network};
    use parsteal::dataflow::task::{NodeId, TaskClass, TaskDesc};
    let (net, mb) = Network::new(3, LinkModel {
        latency_us: 50.0,
        bw_bytes_per_us: 1000.0,
    });
    let net2 = net.clone();
    let sender = std::thread::spawn(move || {
        for i in 0..500u32 {
            net2.send(
                NodeId(0),
                NodeId(1 + (i % 2)),
                Msg::Activate {
                    task: TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0),
                },
            );
        }
    });
    sender.join().unwrap();
    let mut got = 0;
    for mbox in &mb[1..] {
        while mbox
            .recv_timeout(std::time::Duration::from_millis(200))
            .is_some()
        {
            got += 1;
        }
    }
    assert_eq!(got, 500);
    net.shutdown();
}

/// Figure harness smoke test at miniature scale: fig2 text + JSON out.
#[test]
fn figure_harness_smoke() {
    use parsteal::figures::{self, Ctx, Scale};
    let out = std::env::temp_dir().join("parsteal-it-fig");
    let ctx = Ctx::new(Scale::Small, 1, std::path::Path::new("artifacts"), &out);
    // fig5-family sweep is the heaviest; run the lighter fig2 + stats
    let text = figures::run(&ctx, "fig2").unwrap();
    assert!(text.contains("No-Steal"));
    assert!(out.join("fig2.json").exists());
}

/// Config flags round-trip into a working simulation.
#[test]
fn config_to_simulation() {
    use parsteal::config::{RunConfig, Workload};
    use parsteal::util::cli::Args;
    let args = Args::parse(
        "--tiles 8 --tile-size 16 --nodes 2 --workers 2 --victim half --seed 3"
            .split_whitespace()
            .map(String::from),
    )
    .unwrap();
    let cfg = RunConfig::from_args(&args).unwrap();
    let Workload::Cholesky(p) = &cfg.workload else {
        panic!()
    };
    let graph = Arc::new(CholeskyGraph::new(p.clone()));
    let total = graph.total_tasks().unwrap();
    let r = Simulator::new(
        graph,
        cfg.sim_config(),
        CostModel::default_calibrated(),
        cfg.migrate,
        p.tile_size,
    )
    .run();
    assert_eq!(r.tasks_total_executed(), total);
}
