//! loom model-checking suite for the lock-free workassist backend
//! (`--sched workassist`): exhaustively explores thread interleavings
//! of the claim protocol under `RUSTFLAGS="--cfg loom"`, where the
//! backend's atomics are loom's checked twins (see the `sync` shim in
//! `src/sched/workassist.rs`). Each model is deliberately tiny — two
//! threads, a handful of entries — because loom enumerates every
//! reachable interleaving; the properties are the ones the whole PR
//! stands on: no entry is claimed twice, no published task is lost,
//! and the lock-free accounting is exact at every quiesce point.
//!
//! Without `--cfg loom` this whole file compiles to nothing (the
//! regular `cargo test` job runs the property + stress suites instead).
#![cfg(loom)]

use loom::model::Builder;
use loom::sync::Arc;
use loom::thread;

use parsteal::dataflow::task::{TaskClass, TaskDesc};
use parsteal::sched::{BatchSite, Scheduler, TaskMeta, WorkAssistQueue};

fn t(i: u32) -> TaskDesc {
    TaskDesc::indexed(TaskClass::Synthetic, i, 0, 0)
}

fn meta(payload: u64) -> TaskMeta {
    TaskMeta {
        stealable: true,
        payload_bytes: payload,
        class: TaskClass::Synthetic,
    }
}

/// Bounded exhaustive exploration: preemption-bounded at 2, which loom's
/// docs recommend as the bound that still catches practically every
/// bug while keeping tiny models tractable.
fn model(f: impl Fn() + Send + Sync + 'static) {
    let mut b = Builder::new();
    b.preemption_bound = Some(2);
    b.check(f);
}

/// Owner `select` (best end) races a thief `extract_stealable` (worst
/// end): every interleaving conserves both tasks, claims none twice,
/// and leaves the accounting counters exactly zero at quiesce.
#[test]
fn owner_pop_vs_thief_claim_conserve_tasks() {
    model(|| {
        let q = Arc::new(WorkAssistQueue::new(2));
        q.insert_meta(t(0), 5, meta(10));
        q.insert_meta(t(1), 1, meta(20));
        let thief = {
            let q = Arc::clone(&q);
            thread::spawn(move || q.extract_stealable(1))
        };
        let got = q.select(0);
        let stolen = thief.join().unwrap();
        let mut seen = Vec::new();
        seen.extend(got);
        seen.extend(stolen);
        seen.extend(Scheduler::drain(&*q));
        seen.sort_by_key(|d| d.i);
        assert_eq!(seen, vec![t(0), t(1)], "conservation, no double claim");
        assert_eq!(q.len(), 0);
        assert_eq!(q.stealable_count(), 0);
        assert_eq!(q.stealable_payload_bytes(), 0);
        assert_eq!(q.min_stealable_payload_bytes(), u64::MAX);
    });
}

/// Two workers race `select` toward the same best entry: exactly one
/// wins the claim CAS, the loser retries onto the other entry, and
/// both walk away with distinct tasks.
#[test]
fn concurrent_selects_claim_distinct_tasks() {
    model(|| {
        let q = Arc::new(WorkAssistQueue::new(2));
        q.insert_meta(t(0), 3, meta(8));
        q.insert_meta(t(1), 3, meta(9));
        let other = {
            let q = Arc::clone(&q);
            thread::spawn(move || q.select(1))
        };
        let a = q.select(0);
        let b = other.join().unwrap();
        let a = a.expect("two entries, two consumers: each gets one");
        let b = b.expect("two entries, two consumers: each gets one");
        assert_ne!(a, b, "one claim per entry");
        assert_eq!(q.len(), 0);
        assert_eq!(q.stealable_count(), 0);
    });
}

/// An accounting reader (count + flat-combined minimum) races a claim:
/// the counters never over-report past the published set, the combined
/// minimum is exact in every interleaving here (the claimed entry is
/// not the lightest), and the quiesced read is exact.
#[test]
fn accounting_read_races_claim_without_tearing() {
    model(|| {
        let q = Arc::new(WorkAssistQueue::new(1));
        q.insert_meta(t(0), 2, meta(100));
        q.insert_meta(t(1), 4, meta(300));
        let reader = {
            let q = Arc::clone(&q);
            thread::spawn(move || {
                let n = q.stealable_count();
                let min = q.min_stealable_payload_bytes();
                (n, min)
            })
        };
        let got = q.select(0);
        assert_eq!(got, Some(t(1)), "best-first: priority 4 leaves");
        let (n, min) = reader.join().unwrap();
        assert!(n == 1 || n == 2, "count is pre- or post-claim, never torn");
        assert_eq!(min, 100, "the lightest payload stays queued throughout");
        assert_eq!(q.stealable_count(), 1);
        assert_eq!(q.min_stealable_payload_bytes(), 100);
    });
}

/// A work-assisting batch publish (one block, one CAS) races a
/// consumer: the pre-published task is always visible, nothing from
/// the batch is lost or doubled, and quiesced accounting is exact.
#[test]
fn batch_publish_races_select_without_losing_tasks() {
    model(|| {
        let q = Arc::new(WorkAssistQueue::new(2));
        q.insert_meta(t(0), 1, meta(5));
        let publisher = {
            let q = Arc::clone(&q);
            thread::spawn(move || {
                let batch = vec![(t(1), 2, meta(6)), (t(2), 3, meta(7))];
                q.insert_batch_at(BatchSite::Activation, &batch);
            })
        };
        let first = q.select(0);
        publisher.join().unwrap();
        let first = first.expect("a task published before the race is never invisible");
        let mut seen = vec![first];
        seen.extend(Scheduler::drain(&*q));
        seen.sort_by_key(|d| d.i);
        assert_eq!(seen, vec![t(0), t(1), t(2)], "conservation across the batch");
        assert_eq!(q.len(), 0);
        assert_eq!(q.stealable_count(), 0);
        assert_eq!(q.min_stealable_payload_bytes(), u64::MAX);
    });
}
