//! Randomized multi-threaded differential stress test for the
//! lock-free workassist backend: real threads hammer one shared
//! `WorkAssistQueue` with inserts, batch publishes, selects, steal
//! extractions and feedback, each logging exactly what it inserted and
//! removed. At every quiesce point (the join barrier after each round)
//! the linearized log — inserts minus removals — is replayed into a
//! shadow `CentralQueue` oracle, which must agree exactly on length,
//! stealable count, payload sum, *exact* payload minimum, per-class
//! counts and max priority. Across the whole run every task is
//! conserved (claimed exactly once or drained at the end), and the
//! backend must finish with `lock_acquisitions == 0`: contention is
//! absorbed by CAS retries, never by a mutex.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;
use std::thread;

use parsteal::dataflow::task::{TaskClass, TaskDesc};
use parsteal::sched::{BatchSite, CentralQueue, Scheduler, StealOutcome, TaskMeta, WorkAssistQueue};
use parsteal::util::rng::Rng;

const THREADS: usize = 8;
const ROUNDS: usize = 4;
const PER: u32 = 48;

/// What one thread did to the shared queue: every insert (with its
/// priority and meta) and every task it successfully claimed.
type Log = (Vec<(TaskDesc, i64, TaskMeta)>, Vec<TaskDesc>);

fn class_of(i: u32) -> TaskClass {
    TaskClass::ALL[(i as usize) % TaskClass::COUNT]
}

fn t(i: u32) -> TaskDesc {
    TaskDesc::indexed(class_of(i), i, 0, 0)
}

// Meta derived deterministically from the task id, so logs only need
// to carry task identities to reconstruct the full accounting oracle.
fn meta_of(i: u32) -> TaskMeta {
    TaskMeta {
        stealable: i % 3 != 0,
        payload_bytes: 8 + (i as u64 % 11) * 16,
        class: class_of(i),
    }
}

/// One thread's workload: a randomized interleaving of single inserts,
/// batch publishes, owner selects, both steal-extraction paths,
/// accounting reads and steal feedback. Returns the faithful op log.
fn hammer(q: &WorkAssistQueue, seed: u64, base: u32, worker: usize) -> Log {
    let mut rng = Rng::new(seed);
    let mut inserted = Vec::new();
    let mut removed = Vec::new();
    let mut next = base;
    for step in 0..PER {
        if step % 8 == 7 {
            let mut batch = Vec::new();
            for _ in 0..3 {
                let prio = rng.next_u64() as i64 % 100;
                batch.push((t(next), prio, meta_of(next)));
                next += 1;
            }
            q.insert_batch_at(BatchSite::Activation, &batch);
            inserted.extend(batch);
        } else {
            let prio = rng.next_u64() as i64 % 100;
            q.insert_meta(t(next), prio, meta_of(next));
            inserted.push((t(next), prio, meta_of(next)));
            next += 1;
        }
        match rng.below(6) {
            0 => {
                if let Some(task) = q.select(worker) {
                    removed.push(task);
                }
            }
            1 => removed.extend(q.extract_stealable(2)),
            2 => {
                let evens = |task: &TaskDesc| task.i % 2 == 0;
                removed.extend(q.extract_for_steal(2, &evens));
            }
            3 => {
                // Accounting reads race the claims; the values are
                // checked exactly at the quiesce points.
                let _ = q.stealable_count();
                let _ = q.min_stealable_payload_bytes();
                let _ = q.class_counts();
            }
            4 => q.feedback(StealOutcome::Granted),
            _ => {}
        }
    }
    (inserted, removed)
}

#[test]
#[cfg_attr(miri, ignore)] // real threads: minutes under the interpreter
fn stress_differential_against_central_oracle() {
    let q = Arc::new(WorkAssistQueue::new(THREADS));
    let mut live: HashMap<TaskDesc, (i64, TaskMeta)> = HashMap::new();
    let mut ever_removed: HashSet<TaskDesc> = HashSet::new();
    for round in 0..ROUNDS {
        let mut handles = Vec::new();
        for k in 0..THREADS {
            let q = Arc::clone(&q);
            let seed = (round * THREADS + k) as u64 * 0x9E37 + 7;
            let base = ((round * THREADS + k) as u32 + 1) * 1000;
            handles.push(thread::spawn(move || hammer(&q, seed, base, k)));
        }
        let mut logs = Vec::new();
        for handle in handles {
            logs.push(handle.join().unwrap());
        }
        // Linearize: all inserts land before any removal is checked, so
        // cross-thread steals (B removes what A inserted) resolve.
        for (inserted, _) in &logs {
            for &(task, prio, meta) in inserted {
                live.insert(task, (prio, meta));
            }
        }
        for (_, removed) in &logs {
            for &task in removed {
                assert!(ever_removed.insert(task), "task {task} claimed twice");
                assert!(live.remove(&task).is_some(), "removed {task} never inserted");
            }
        }
        // Quiesce point: replay the surviving set into a shadow central
        // queue and compare every accounting surface exactly.
        let oracle = CentralQueue::new();
        for (task, (prio, meta)) in &live {
            oracle.insert_meta(*task, *prio, *meta);
        }
        assert_eq!(q.len(), oracle.len(), "round {round}: len diverged");
        assert_eq!(q.stealable_count(), oracle.stealable_count(), "round {round}: count");
        assert_eq!(
            q.stealable_payload_bytes(),
            oracle.stealable_payload_bytes(),
            "round {round}: payload sum diverged"
        );
        assert_eq!(
            q.min_stealable_payload_bytes(),
            oracle.min_stealable_payload_bytes(),
            "round {round}: exact payload minimum diverged"
        );
        assert_eq!(q.class_counts(), oracle.class_counts(), "round {round}: class counts");
        assert_eq!(q.max_priority(), oracle.max_priority(), "round {round}: max priority");
        assert_eq!(q.stats().min_payload_resets, 0, "round {round}: conservative reset");
    }
    // Final conservation: drain returns each surviving task exactly once.
    let drained = q.drain();
    assert_eq!(drained.len(), live.len(), "drain disagrees with the live set");
    let unique: HashSet<TaskDesc> = drained.iter().copied().collect();
    assert_eq!(unique.len(), drained.len(), "duplicate task in drain");
    for task in &drained {
        assert!(live.contains_key(task), "drained {task} was never live");
    }
    assert!(q.is_empty(), "queue not empty after drain");
    let stats = q.stats();
    assert_eq!(stats.lock_acquisitions, 0, "workassist took a lock under stress");
    let claimed = stats.selects + stats.steal_extracted;
    assert_eq!(claimed, ever_removed.len() as u64, "claim stats disagree with the log");
}
