"""Pure-jnp oracles for the Pallas tile kernels.

These are the CORE correctness references: every Pallas kernel in this
package must match the corresponding function here (pytest enforces it).
All operate on a single tile (the unit of work in the sparse tiled
Cholesky workload of the paper) and mirror the BLAS/LAPACK calls PaRSEC's
DPLASMA Cholesky issues per task type:

  POTRF:  L = chol(A)                (diagonal tile factorization)
  TRSM:   X = B @ inv(L)^T           (panel solve against the diag tile)
  SYRK:   C = C - A @ A^T            (symmetric rank-k trailing update)
  GEMM:   C = C - A @ B^T            (general trailing update)
"""

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl


def ref_potrf(a: jax.Array) -> jax.Array:
    """Lower-triangular Cholesky factor of an SPD tile."""
    return jnp.linalg.cholesky(a)


def ref_trsm(l: jax.Array, b: jax.Array) -> jax.Array:
    """Solve X * L^T = B for X (L lower triangular, non-unit diagonal)."""
    # X = B @ inv(L)^T  <=>  L X^T = B^T (forward substitution)
    return jsl.solve_triangular(l, b.T, lower=True).T


def ref_syrk(c: jax.Array, a: jax.Array) -> jax.Array:
    """Symmetric rank-k update C - A @ A^T (full matrix; symmetry implicit)."""
    return c - a @ a.T


def ref_gemm(c: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """Trailing-matrix update C - A @ B^T."""
    return c - a @ b.T


def ref_potrf_trsm(a: jax.Array, b: jax.Array):
    """Fused diagonal factorization + one panel solve.

    Returns (L, X) with L = chol(A) and X = B inv(L)^T. Used by the fused
    artifact that collapses the POTRF->TRSM dependency chain into one
    executable when both tiles live on the same node.
    """
    l = ref_potrf(a)
    return l, ref_trsm(l, b)


def spd(n: int, key: jax.Array, dtype=jnp.float64) -> jax.Array:
    """Random symmetric positive-definite tile (test helper)."""
    m = jax.random.normal(key, (n, n), dtype=dtype)
    return m @ m.T + n * jnp.eye(n, dtype=dtype)
