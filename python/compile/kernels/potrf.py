"""Pallas POTRF kernel: L = chol(A) for one SPD diagonal tile.

Right-looking unblocked Cholesky with *masked full-width updates*: at
column j the trailing submatrix update is expressed as a rank-1 outer
product over the full (n, n) tile with an iota mask selecting rows > j
and cols > j. All shapes are static, so the loop body is a fixed VPU/MXU
pattern; the tile stays resident in VMEM for the whole factorization.

There is exactly one POTRF per panel in the Cholesky DAG (O(T) of them),
so this kernel is latency- not throughput-critical; the masked-update
form is chosen for lowering simplicity over asymptotic efficiency.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _potrf_kernel(a_ref, o_ref):
    a = a_ref[...]
    n = a.shape[0]
    rows = jax.lax.iota(jnp.int32, n)  # row indices

    def body(j, m):
        djj = jax.lax.dynamic_slice(m, (j, j), (1, 1))[0, 0]
        d = jnp.sqrt(djj)
        colj = jax.lax.dynamic_slice_in_dim(m, j, 1, axis=1)[:, 0]
        below = jnp.where(rows > j, colj / d, jnp.zeros_like(colj))
        # Final column j: diagonal = d, below-diagonal = scaled column.
        newcol = below + jnp.where(rows == j, d, jnp.zeros_like(colj))
        m = jax.lax.dynamic_update_slice_in_dim(m, newcol[:, None], j, axis=1)
        # Trailing update: m[i, k] -= l[i, j] * l[k, j] for i, k > j.
        # `below` is already zero for rows <= j; mask columns <= j too so
        # the freshly written column j is untouched.
        colmask = (rows > j)[None, :]
        return m - jnp.where(colmask, jnp.outer(below, below), jnp.zeros_like(m))

    m = jax.lax.fori_loop(0, n, body, a)
    o_ref[...] = jnp.tril(m)


@jax.jit
def potrf(a: jax.Array) -> jax.Array:
    """Lower Cholesky factor of an SPD tile. Shape: (n, n) -> (n, n)."""
    n = a.shape[0]
    return pl.pallas_call(
        _potrf_kernel,
        out_shape=jax.ShapeDtypeStruct((n, n), a.dtype),
        interpret=True,
    )(a)
