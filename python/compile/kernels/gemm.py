"""Pallas GEMM trailing-update kernel: C <- C - A @ B^T.

This is the compute hot-spot of the paper's sparse Cholesky workload —
GEMM tasks dominate the DAG (O(T^3) of them vs O(T^2) TRSM/SYRK and O(T)
POTRF for a T x T tile matrix), so this kernel is the one the performance
pass cares about.

Structure (TPU idiom, see DESIGN.md §Hardware-Adaptation):
  * grid over the K dimension; each step streams one (m, bk) panel of A
    and one (n, bk) panel of B from HBM into VMEM while the MXU consumes
    the previous one (double-buffered by the Pallas pipeline machinery);
  * the output block stays resident in VMEM across the whole K loop and
    is initialized from C at k == 0 (accumulator-in-VMEM pattern);
  * `interpret=True` everywhere — the CPU PJRT plugin cannot execute
    Mosaic custom-calls; real-TPU numbers are estimated analytically in
    DESIGN.md §Perf from the BlockSpec footprint.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget per operand block (f32 elements). 128 x 128 x 4 B = 64 KiB
# per block; three resident operand blocks + accumulator stay well under
# the ~16 MiB VMEM of a TPU core even at f64.
MAX_BLOCK_K = 128


def _gemm_kernel(a_ref, b_ref, c_ref, o_ref):
    """One K-step: o += (k==0 ? c : 0) - a @ b^T."""
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = c_ref[...]

    a = a_ref[...]
    b = b_ref[...]
    o_ref[...] = o_ref[...] - jax.lax.dot_general(
        a,
        b,
        # contract A's K axis (1) with B's K axis (1): (m, bk) x (n, bk) -> (m, n)
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=o_ref.dtype,
    )


@functools.partial(jax.jit, static_argnames=("block_k",))
def gemm(c: jax.Array, a: jax.Array, b: jax.Array, *, block_k: int | None = None) -> jax.Array:
    """Tile update C - A @ B^T as a K-blocked Pallas kernel.

    Shapes: c (m, n), a (m, k), b (n, k). Returns (m, n).
    """
    m, n = c.shape
    kk = a.shape[1]
    if block_k is None:
        block_k = min(kk, MAX_BLOCK_K)
    # Pad K so the grid divides evenly; zero panels contribute nothing.
    pad = (-kk) % block_k
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, pad)))
        kk += pad
    nk = kk // block_k

    return pl.pallas_call(
        _gemm_kernel,
        grid=(nk,),
        in_specs=[
            pl.BlockSpec((m, block_k), lambda k: (0, k)),
            pl.BlockSpec((n, block_k), lambda k: (0, k)),
            pl.BlockSpec((m, n), lambda k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, n), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        interpret=True,
    )(a, b, c)
