"""Pallas TRSM kernel: solve X * L^T = B (right-side, lower-triangular,
transposed — the DPLASMA Cholesky panel solve).

The solve is a forward substitution over the columns of X:

    X[:, j] = (B[:, j] - X[:, :j] @ L[j, :j]) / L[j, j]

The sequential j-loop is inherent to the operation, so the kernel holds
the whole (m, n) X in VMEM (tiles are <= 128^2, comfortably resident) and
expresses each step as a full-width masked matvec — a static-shape MXU
op — rather than growing dynamic slices. On TPU this trades O(n) small
matvecs for MXU-friendly fixed shapes; on the interpret path it keeps
everything traceable.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _trsm_kernel(l_ref, b_ref, o_ref):
    l = l_ref[...]
    b = b_ref[...]
    n = l.shape[0]
    cols = jax.lax.iota(jnp.int32, n)

    def body(j, x):
        lrow = jax.lax.dynamic_slice_in_dim(l, j, 1, axis=0)[0]  # L[j, :]
        # Mask to the strictly-lower part L[j, :j]; the rest of the row is
        # junk above the diagonal and must not contribute.
        lrow_masked = jnp.where(cols < j, lrow, jnp.zeros_like(lrow))
        acc = x @ lrow_masked  # (m,) = X[:, :j] @ L[j, :j]
        bj = jax.lax.dynamic_slice_in_dim(b, j, 1, axis=1)[:, 0]
        diag = jax.lax.dynamic_slice_in_dim(lrow, j, 1, axis=0)[0]
        xj = (bj - acc) / diag
        return jax.lax.dynamic_update_slice_in_dim(x, xj[:, None], j, axis=1)

    x0 = jnp.zeros_like(b)
    o_ref[...] = jax.lax.fori_loop(0, n, body, x0)


@jax.jit
def trsm(l: jax.Array, b: jax.Array) -> jax.Array:
    """X = B @ inv(L)^T. Shapes: l (n, n) lower-triangular, b (m, n)."""
    m, n = b.shape
    return pl.pallas_call(
        _trsm_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), b.dtype),
        interpret=True,
    )(l, b)
