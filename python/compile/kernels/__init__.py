"""L1: Pallas tile kernels for the sparse Cholesky workload.

Every kernel here is authored for TPU structure but lowered with
`interpret=True` so the emitted HLO runs on any PJRT backend (the Rust
coordinator uses the CPU plugin). Correctness oracles live in `ref.py`.
"""

import jax

# The paper's workload uses 64-bit elements throughout; keep f64 enabled
# for every consumer of this package (kernels, model, aot, tests).
jax.config.update("jax_enable_x64", True)

from .gemm import gemm  # noqa: E402
from .potrf import potrf  # noqa: E402
from .syrk import syrk  # noqa: E402
from .trsm import trsm  # noqa: E402

__all__ = ["gemm", "syrk", "trsm", "potrf"]
