"""Pallas SYRK kernel: C <- C - A @ A^T (symmetric rank-k trailing update).

Same K-streaming / VMEM-resident-accumulator structure as `gemm.py`; A is
passed once and indexed twice by the BlockSpecs, so HBM traffic per K-step
is a single (n, bk) panel. The full (n, n) result is produced — the
Cholesky DAG only ever reads the lower triangle, and keeping the write
dense avoids a masked store on the MXU path.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gemm import MAX_BLOCK_K


def _syrk_kernel(a_ref, c_ref, o_ref):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = c_ref[...]

    a = a_ref[...]
    o_ref[...] = o_ref[...] - jax.lax.dot_general(
        a,
        a,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=o_ref.dtype,
    )


@functools.partial(jax.jit, static_argnames=("block_k",))
def syrk(c: jax.Array, a: jax.Array, *, block_k: int | None = None) -> jax.Array:
    """Tile update C - A @ A^T. Shapes: c (n, n), a (n, k)."""
    n = c.shape[0]
    kk = a.shape[1]
    if block_k is None:
        block_k = min(kk, MAX_BLOCK_K)
    pad = (-kk) % block_k
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad)))
        kk += pad
    nk = kk // block_k

    return pl.pallas_call(
        _syrk_kernel,
        grid=(nk,),
        in_specs=[
            pl.BlockSpec((n, block_k), lambda k: (0, k)),
            pl.BlockSpec((n, n), lambda k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, n), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), c.dtype),
        interpret=True,
    )(a, c)
