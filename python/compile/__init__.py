"""Build-time compile package: L1 Pallas kernels + L2 model + AOT lowering.

Runs once under `make artifacts`; never imported on the request path.
"""
