"""L2: the JAX compute graph for the paper's workload tasks.

The sparse tiled Cholesky DAG has four task classes (POTRF, TRSM, SYRK,
GEMM — §4.1 of the paper). Each task body is one of the functions below,
built on the L1 Pallas kernels, plus a fused POTRF+TRSM variant that
collapses the panel-head dependency chain when both tiles are resident on
the same node.

These functions are lowered ONCE by `aot.py` into per-(op, tile-size) HLO
text artifacts; the Rust coordinator loads and executes them via PJRT and
Python never appears on the request path.
"""

from typing import Tuple

import jax
import jax.numpy as jnp

from .kernels import gemm as _gemm
from .kernels import potrf as _potrf
from .kernels import syrk as _syrk
from .kernels import trsm as _trsm


def potrf_step(a: jax.Array) -> Tuple[jax.Array]:
    """POTRF task body: factorize a diagonal tile."""
    return (_potrf(a),)


def trsm_step(l: jax.Array, b: jax.Array) -> Tuple[jax.Array]:
    """TRSM task body: panel solve B <- B inv(L)^T."""
    return (_trsm(l, b),)


def syrk_step(c: jax.Array, a: jax.Array) -> Tuple[jax.Array]:
    """SYRK task body: diagonal trailing update C <- C - A A^T."""
    return (_syrk(c, a),)


def gemm_step(c: jax.Array, a: jax.Array, b: jax.Array) -> Tuple[jax.Array]:
    """GEMM task body: off-diagonal trailing update C <- C - A B^T."""
    return (_gemm(c, a, b),)


def potrf_trsm_step(a: jax.Array, b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Fused POTRF + one TRSM (ablation artifact; see DESIGN.md)."""
    l = _potrf(a)
    return (l, _trsm(l, b))


#: op name -> (fn, number of tile inputs, number of tile outputs)
OPS = {
    "potrf": (potrf_step, 1, 1),
    "trsm": (trsm_step, 2, 1),
    "syrk": (syrk_step, 2, 1),
    "gemm": (gemm_step, 3, 1),
    "potrf_trsm": (potrf_trsm_step, 2, 2),
}


def dense_block_cholesky(tiles: jax.Array) -> jax.Array:
    """Blocked right-looking Cholesky over a (T, T, n, n) tile array.

    Pure L2 composition of the task bodies in DAG order — the same
    schedule the Rust coordinator executes distributed. Used by tests to
    validate that the per-tile kernels compose into a correct global
    factorization, and as the oracle for the end-to-end example.
    Returns the (T, T, n, n) lower-triangular tile factor.
    """
    t = tiles.shape[0]
    tiles = [[tiles[i, j] for j in range(t)] for i in range(t)]
    for k in range(t):
        (tiles[k][k],) = potrf_step(tiles[k][k])
        for i in range(k + 1, t):
            (tiles[i][k],) = trsm_step(tiles[k][k], tiles[i][k])
        for i in range(k + 1, t):
            (tiles[i][i],) = syrk_step(tiles[i][i], tiles[i][k])
            for j in range(k + 1, i):
                (tiles[i][j],) = gemm_step(tiles[i][j], tiles[i][k], tiles[j][k])
    z = jnp.zeros_like(tiles[0][0])
    return jnp.stack(
        [jnp.stack([tiles[i][j] if j <= i else z for j in range(t)]) for i in range(t)]
    )
