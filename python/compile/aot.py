"""AOT pipeline: lower every (op, tile-size) pair to HLO *text*.

HLO text — NOT `lowered.compiler_ir(...).serialize()` — is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage (invoked by `make artifacts`, from python/):

    python -m compile.aot --outdir ../artifacts [--sizes 8,16,32,...]

Emits artifacts/<op>_n<size>_f64.hlo.txt per entry plus manifest.json
describing every artifact (op, tile size, dtype, input/output arity) for
the Rust runtime loader.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import OPS

# Tile sizes the Rust side needs: {8,16,24,32} for tests + the end-to-end
# example, {10,20,30,40,50} for the Table 1 granularity sweep and the DES
# cost-model calibration.
DEFAULT_SIZES = (8, 10, 16, 20, 24, 30, 32, 40, 50)
DTYPE = jnp.float64
DTYPE_TAG = "f64"


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_op(op: str, n: int) -> str:
    fn, arity, _ = OPS[op]
    spec = jax.ShapeDtypeStruct((n, n), DTYPE)
    return to_hlo_text(jax.jit(fn).lower(*([spec] * arity)))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--sizes", default=",".join(str(s) for s in DEFAULT_SIZES))
    ap.add_argument("--ops", default=",".join(OPS))
    args = ap.parse_args()

    sizes = [int(s) for s in args.sizes.split(",") if s]
    ops = [o for o in args.ops.split(",") if o]
    os.makedirs(args.outdir, exist_ok=True)

    manifest = {"dtype": DTYPE_TAG, "entries": []}
    for op in ops:
        _, arity, n_out = OPS[op]
        for n in sizes:
            name = f"{op}_n{n}_{DTYPE_TAG}"
            path = os.path.join(args.outdir, name + ".hlo.txt")
            text = lower_op(op, n)
            with open(path, "w") as f:
                f.write(text)
            manifest["entries"].append(
                {
                    "name": name,
                    "op": op,
                    "tile": n,
                    "dtype": DTYPE_TAG,
                    "inputs": arity,
                    "outputs": n_out,
                    "file": os.path.basename(path),
                    "sha256": hashlib.sha256(text.encode()).hexdigest(),
                }
            )
            print(f"  lowered {name}: {len(text)} chars", file=sys.stderr)

    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(
        f"wrote {len(manifest['entries'])} artifacts + manifest.json to {args.outdir}",
        file=sys.stderr,
    )


if __name__ == "__main__":
    main()
