"""L2 composition tests: task bodies compose into a correct global
factorization in DAG order, exactly as the Rust coordinator executes them."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import spd
from compile.model import OPS, dense_block_cholesky


def tiled_spd(t, n, seed):
    big = spd(t * n, jax.random.PRNGKey(seed))
    return big, big.reshape(t, n, t, n).transpose(0, 2, 1, 3)


def assemble(tiles):
    t, _, n, _ = tiles.shape
    return np.array(tiles.transpose(0, 2, 1, 3).reshape(t * n, t * n))


@settings(max_examples=8, deadline=None)
@given(t=st.integers(1, 4), n=st.integers(1, 12), seed=st.integers(0, 10_000))
def test_block_cholesky_matches_lapack(t, n, seed):
    big, tiles = tiled_spd(t, n, seed)
    lt = dense_block_cholesky(tiles)
    np.testing.assert_allclose(
        assemble(lt), np.linalg.cholesky(np.array(big)), rtol=1e-8, atol=1e-8
    )


@pytest.mark.parametrize("t,n", [(2, 8), (3, 8), (4, 4), (5, 10)])
def test_block_cholesky_reconstructs(t, n):
    big, tiles = tiled_spd(t, n, seed=t * 100 + n)
    l = assemble(dense_block_cholesky(tiles))
    np.testing.assert_allclose(l @ l.T, np.array(big), rtol=1e-8, atol=1e-8)


def test_ops_registry_arity():
    """The manifest arities the Rust loader trusts must match the fns."""
    import inspect

    for name, (fn, arity, n_out) in OPS.items():
        assert len(inspect.signature(fn).parameters) == arity, name
        spec = jax.ShapeDtypeStruct((4, 4), jnp.float64)
        a = spd(4, jax.random.PRNGKey(0))
        args = [a if i == 0 else jnp.eye(4) * 2 + 1e-3 for i in range(arity)]
        out = fn(*args)
        assert isinstance(out, tuple) and len(out) == n_out, name


def test_task_bodies_are_pure():
    """Same inputs -> same outputs (needed for task recreation on steal)."""
    from compile.model import gemm_step

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(9), 3)
    c = jax.random.normal(k1, (16, 16), jnp.float64)
    a = jax.random.normal(k2, (16, 16), jnp.float64)
    b = jax.random.normal(k3, (16, 16), jnp.float64)
    (o1,) = gemm_step(c, a, b)
    (o2,) = gemm_step(c, a, b)
    np.testing.assert_array_equal(np.array(o1), np.array(o2))
