"""AOT pipeline tests: lowering produces parseable HLO text with the
layout the Rust loader expects (f64 params, tuple return)."""

import json
import os
import subprocess
import sys
import tempfile

import pytest

from compile.aot import lower_op, to_hlo_text
from compile.model import OPS


@pytest.mark.parametrize("op", list(OPS))
def test_lower_op_emits_hlo_text(op):
    text = lower_op(op, 8)
    assert text.startswith("HloModule")
    assert "f64[8,8]" in text
    # return_tuple=True: root must be a tuple for rust's to_tuple().
    assert "->(" in text.replace(" ", "")


def test_lowered_gemm_param_count():
    text = lower_op("gemm", 8)
    # entry computation signature has exactly 3 f64[8,8] params
    header = text.splitlines()[0]
    assert header.count("f64[8,8]") == 4  # 3 inputs + 1 tuple output


def test_manifest_written(tmp_path):
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--outdir", str(tmp_path),
         "--sizes", "8", "--ops", "gemm,potrf"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["dtype"] == "f64"
    assert {e["op"] for e in manifest["entries"]} == {"gemm", "potrf"}
    for e in manifest["entries"]:
        assert (tmp_path / e["file"]).exists()
        assert e["inputs"] == OPS[e["op"]][1]


def test_hlo_text_is_deterministic():
    """Two lowerings of the same op must hash identically (cache key)."""
    assert lower_op("syrk", 8) == lower_op("syrk", 8)
