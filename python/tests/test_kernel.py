"""Kernel-vs-reference correctness: the CORE L1 signal.

Every Pallas kernel must match its pure-jnp oracle in `ref.py` across a
hypothesis sweep of shapes and dtypes, plus fixed cases at the exact tile
sizes the AOT pipeline emits (8..50).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gemm, potrf, syrk, trsm
from compile.kernels.ref import (
    ref_gemm,
    ref_potrf,
    ref_potrf_trsm,
    ref_syrk,
    ref_trsm,
    spd,
)

AOT_SIZES = (8, 10, 16, 20, 24, 30, 32, 40, 50)
DTYPES = (jnp.float32, jnp.float64)


def tol(dtype):
    return dict(rtol=2e-4, atol=2e-4) if dtype == jnp.float32 else dict(rtol=1e-9, atol=1e-9)


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, dtype)


# ---------------------------------------------------------------- GEMM


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 40),
    n=st.integers(1, 40),
    k=st.integers(1, 40),
    dti=st.integers(0, 1),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_matches_ref(m, n, k, dti, seed):
    dtype = DTYPES[dti]
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    c, a, b = rand(k1, (m, n), dtype), rand(k2, (m, k), dtype), rand(k3, (n, k), dtype)
    np.testing.assert_allclose(gemm(c, a, b), ref_gemm(c, a, b), **tol(dtype))


@pytest.mark.parametrize("n", AOT_SIZES)
def test_gemm_aot_sizes(n):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(n), 3)
    c, a, b = (rand(k, (n, n), jnp.float64) for k in (k1, k2, k3))
    np.testing.assert_allclose(gemm(c, a, b), ref_gemm(c, a, b), rtol=1e-11)


@pytest.mark.parametrize("block_k", [1, 3, 8, 128])
def test_gemm_block_k_invariance(block_k):
    """K-blocking (incl. padding path) must not change the result."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    c = rand(k1, (17, 13), jnp.float64)
    a = rand(k2, (17, 29), jnp.float64)
    b = rand(k3, (13, 29), jnp.float64)
    np.testing.assert_allclose(
        gemm(c, a, b, block_k=block_k), ref_gemm(c, a, b), rtol=1e-11
    )


def test_gemm_zero_update():
    """A == 0 must leave C unchanged (sparse-tile no-op path)."""
    c = rand(jax.random.PRNGKey(2), (16, 16), jnp.float64)
    z = jnp.zeros((16, 8), jnp.float64)
    np.testing.assert_allclose(gemm(c, z, jnp.ones((16, 8))), c, rtol=1e-12)


# ---------------------------------------------------------------- SYRK


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 40),
    k=st.integers(1, 40),
    dti=st.integers(0, 1),
    seed=st.integers(0, 2**31 - 1),
)
def test_syrk_matches_ref(n, k, dti, seed):
    dtype = DTYPES[dti]
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    c, a = rand(k1, (n, n), dtype), rand(k2, (n, k), dtype)
    np.testing.assert_allclose(syrk(c, a), ref_syrk(c, a), **tol(dtype))


def test_syrk_preserves_symmetry():
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    c0 = rand(k1, (24, 24), jnp.float64)
    c = c0 + c0.T
    out = syrk(c, rand(k2, (24, 12), jnp.float64))
    np.testing.assert_allclose(out, out.T, rtol=1e-11)


# ---------------------------------------------------------------- TRSM


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 32),
    n=st.integers(1, 32),
    dti=st.integers(0, 1),
    seed=st.integers(0, 2**31 - 1),
)
def test_trsm_matches_ref(m, n, dti, seed):
    dtype = DTYPES[dti]
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    l = ref_potrf(spd(n, k1, dtype))
    b = rand(k2, (m, n), dtype)
    np.testing.assert_allclose(trsm(l, b), ref_trsm(l, b), **tol(dtype))


@pytest.mark.parametrize("n", AOT_SIZES)
def test_trsm_roundtrip(n):
    """(B inv(L)^T) L^T == B — the algebraic contract the DAG relies on."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(n))
    l = ref_potrf(spd(n, k1))
    b = rand(k2, (n, n), jnp.float64)
    x = trsm(l, b)
    np.testing.assert_allclose(x @ l.T, b, rtol=1e-8, atol=1e-8)


def test_trsm_identity():
    b = rand(jax.random.PRNGKey(4), (8, 8), jnp.float64)
    np.testing.assert_allclose(trsm(jnp.eye(8), b), b, rtol=1e-12)


def test_trsm_ignores_upper_junk():
    """Entries above L's diagonal must not affect the solve."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    l = ref_potrf(spd(12, k1))
    junk = l + jnp.triu(jnp.full((12, 12), 7.0), k=1)
    b = rand(k2, (9, 12), jnp.float64)
    np.testing.assert_allclose(trsm(junk, b), trsm(l, b), rtol=1e-12)


# --------------------------------------------------------------- POTRF


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 40), dti=st.integers(0, 1), seed=st.integers(0, 2**31 - 1))
def test_potrf_matches_ref(n, dti, seed):
    dtype = DTYPES[dti]
    a = spd(n, jax.random.PRNGKey(seed), dtype)
    np.testing.assert_allclose(potrf(a), ref_potrf(a), **tol(dtype))


@pytest.mark.parametrize("n", AOT_SIZES)
def test_potrf_reconstructs(n):
    """L L^T == A at every AOT tile size."""
    a = spd(n, jax.random.PRNGKey(n * 7 + 1))
    l = potrf(a)
    np.testing.assert_allclose(l @ l.T, a, rtol=1e-9, atol=1e-9)
    # strictly lower-triangular output
    np.testing.assert_allclose(l, jnp.tril(l), rtol=0, atol=0)


def test_potrf_diagonal_matrix():
    d = jnp.diag(jnp.arange(1.0, 9.0))
    np.testing.assert_allclose(potrf(d), jnp.diag(jnp.sqrt(jnp.arange(1.0, 9.0))), rtol=1e-12)


def test_potrf_1x1():
    np.testing.assert_allclose(potrf(jnp.array([[4.0]])), jnp.array([[2.0]]), rtol=1e-12)


# ------------------------------------------------------ fused POTRF+TRSM


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 24), m=st.integers(1, 24), seed=st.integers(0, 2**31 - 1))
def test_fused_potrf_trsm(n, m, seed):
    from compile.model import potrf_trsm_step

    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = spd(n, k1)
    b = rand(k2, (m, n), jnp.float64)
    l, x = potrf_trsm_step(a, b)
    rl, rx = ref_potrf_trsm(a, b)
    np.testing.assert_allclose(l, rl, rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(x, rx, rtol=1e-8, atol=1e-8)
